//! batch-lp2d CLI: the leader entrypoint over the library.
//!
//! Subcommands (hand-rolled parsing; the offline vendor set has no clap):
//!
//!   info                              -- platform + artifact inventory
//!   solve    [--batch N] [--m M] ...  -- generate + solve one batch
//!   serve    [--requests N] ...       -- run the coordinator under load
//!   tune     [--backends LIST] ...    -- profile backends, write TUNE_profile.json
//!   crowd    [--agents N] ...         -- crowd simulation end to end
//!   figures  [--fig 3a|3b|3c|4a|4b|5|7a|7b|imbalance|all]
//!                                     -- regenerate the paper's figures
//!
//! Everything prints TSV or markdown tables suitable for EXPERIMENTS.md.

// Mirror the library crate root's style-lint policy (see src/lib.rs).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::excessive_precision,
    clippy::many_single_char_names,
    clippy::manual_range_contains
)]

use std::collections::HashMap;

use batch_lp2d::bench::figures::{self, FigureCtx};
use batch_lp2d::bench::imbalance;
use batch_lp2d::coordinator::{BackendSpec, Config, Service};
use batch_lp2d::gen::{self, trace};
use batch_lp2d::lp::types::Status;
use batch_lp2d::obs::export::{write_chrome_trace, write_metrics_exposition};
use batch_lp2d::obs::spans::SpanRecorder;
use batch_lp2d::runtime::{Engine, PipelineDepth, Variant};
use batch_lp2d::sim::{Backend, World, WorldParams};
use batch_lp2d::solvers::batch_cpu::{self, Algo};
use batch_lp2d::trace::{
    render_frame, render_frame_with_history, SnapshotRing, TraceCapture, CLEAR, TRACE_SCHEMA,
};
use batch_lp2d::util::{Rng, Timer};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, flags) = parse(&args);
    let result = match cmd.as_str() {
        "info" => cmd_info(&flags),
        "solve" => cmd_solve(&flags),
        "serve" => cmd_serve(&flags),
        "tune" => cmd_tune(&flags),
        "crowd" => cmd_crowd(&flags),
        "figures" => cmd_figures(&flags),
        "help" | "" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "batch-lp2d -- batch 2-D linear programming (Charlton et al., JPDC 2019)\n\
         \n\
         usage: batch-lp2d <command> [--flag value]...\n\
         \n\
         commands:\n\
           info                         platform + compiled artifact inventory\n\
           solve    --batch 1024 --m 64 [--variant rgb|naive|simplex] [--seed S]\n\
                                        generate and solve one batch, print timing\n\
           serve    --requests 6000 [--rate 2000] [--max-wait-ms 2] [--shards 1]\n\
                    [--depth 2] [--backends engine,cpu,batch-cpu:N,simd-cpu:N,simd-cpu-f32:N]\n\
                    [--policy fixed|adaptive] [--max-queue N] [--slo-ms MS]\n\
                    [--bulk-slo-ms MS] [--scenario poisson|bursty|...|trace:PATH]\n\
                    [--tune-profile TUNE_profile.json]\n\
                    [--class-overrides '16:slo-ms=1;64:max-batch=128']\n\
                    [--capture TRACE_run.json] [--capture-sample K]\n\
                    [--replay-speed X] [--passes N]\n\
                    [--tui] [--tui-frame]\n\
                    [--spans-out SPANS_run.json] [--span-sample K]\n\
                    [--metrics-out METRICS_run.prom]\n\
                    [--cache-capacity N] [--cache-eps E] [--warm-start]\n\
                                        run the coordinator under open-loop load\n\
                                        (--backends mixes shard types; CPU-only\n\
                                        mixes serve without artifacts; --policy\n\
                                        picks the admission batch-close policy,\n\
                                        --max-queue bounds queueing with load\n\
                                        shedding, --slo-ms sets the interactive\n\
                                        SLO, --scenario picks a traffic model or\n\
                                        replays a captured trace, --replay-speed\n\
                                        time-compresses a trace replay by X,\n\
                                        --passes serves the same stream N times\n\
                                        through one service (repeat passes hit\n\
                                        the result cache), --tune-profile\n\
                                        calibrates dispatch from measured costs,\n\
                                        --class-overrides sets per-size-class\n\
                                        max-batch/SLO bounds, --capture records\n\
                                        admitted traffic to a replayable trace\n\
                                        fixture (--capture-sample keeps every\n\
                                        K-th request; replay scales the rate\n\
                                        back up), --spans-out writes a Chrome\n\
                                        trace-event JSON span timeline for\n\
                                        Perfetto (--span-sample records every\n\
                                        K-th request), --metrics-out writes a\n\
                                        Prometheus text exposition of the\n\
                                        final snapshot, --cache-capacity enables the\n\
                                        content-addressed result cache (N entries),\n\
                                        --cache-eps quantizes its keys, --warm-start\n\
                                        seeds packed batches from cached results,\n\
                                        --tui renders a live terminal\n\
                                        dashboard, --tui-frame dumps one final\n\
                                        dashboard frame after the run)\n\
           tune     [--backends cpu,batch-cpu:4,simd-cpu:4,simd-cpu-f32:4]\n\
                    [--out TUNE_profile.json]\n\
                    [--runs 3] [--max-batch 512] [--variant rgb]\n\
                                        profile each backend kind over the\n\
                                        (batch x class) grid, fit setup/marginal\n\
                                        cost models, print nominal vs calibrated\n\
                                        weights, and merge the fits into the\n\
                                        profile (idempotent)\n\
           crowd    --agents 512 --steps 100 [--backend engine|cpu]\n\
                                        crowd simulation (paper Sec. 5 application)\n\
           figures  --fig all|3a|3b|3c|4a|4b|5|7a|7b|imbalance|shards|depth|loadgen|simd\n\
                    [--fast]            regenerate the paper's figures as tables\n\
         \n\
         flags:\n\
           --artifacts DIR              artifact directory (default: artifacts)"
    );
}

type Flags = HashMap<String, String>;

fn parse(args: &[String]) -> (String, Flags) {
    let mut cmd = String::new();
    let mut flags = Flags::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "1".to_string()
            };
            flags.insert(name.to_string(), val);
        } else if cmd.is_empty() {
            cmd = a.clone();
        } else {
            eprintln!("ignoring stray argument '{a}'");
        }
        i += 1;
    }
    (cmd, flags)
}

fn flag<T: std::str::FromStr>(flags: &Flags, name: &str, default: T) -> T {
    flags
        .get(name)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn artifact_dir(flags: &Flags) -> String {
    flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".to_string())
}

fn cmd_info(flags: &Flags) -> anyhow::Result<()> {
    let engine = Engine::new(artifact_dir(flags))?;
    println!("platform: {}", engine.platform());
    println!("artifacts ({}):", engine.manifest().dir.display());
    for b in &engine.manifest().buckets {
        println!(
            "  {:<8} batch={:<6} m={:<5} block_b={:<4} chunk={:<4} {}",
            b.variant.as_str(),
            b.batch,
            b.m,
            b.block_b,
            b.chunk,
            b.path.file_name().unwrap().to_string_lossy()
        );
    }
    Ok(())
}

fn cmd_solve(flags: &Flags) -> anyhow::Result<()> {
    let batch = flag(flags, "batch", 1024usize);
    let m = flag(flags, "m", 64usize);
    let seed = flag(flags, "seed", 2019u64);
    let variant = match flags.get("variant").map(String::as_str) {
        None | Some("rgb") => Variant::Rgb,
        Some("naive") => Variant::Naive,
        Some("simplex") => Variant::Simplex,
        Some("ref") => Variant::Ref,
        Some(v) => anyhow::bail!("unknown variant '{v}'"),
    };
    let engine = Engine::new(artifact_dir(flags))?;
    let mut rng = Rng::new(seed);
    let problems = gen::independent_batch(&mut rng, batch, m);

    // Warm (compile) then measure.
    let t = Timer::start();
    engine.solve(variant, &problems, Some(&mut rng))?;
    let compile_ms = t.elapsed_ms();
    let t = Timer::start();
    let (solutions, timing) = engine.solve(variant, &problems, Some(&mut rng))?;
    let solve_ms = t.elapsed_ms();

    let infeasible = solutions.iter().filter(|s| s.status == Status::Infeasible).count();
    println!("variant={} batch={batch} m={m}", variant.as_str());
    println!("first-call (incl. XLA compile): {compile_ms:.1} ms");
    println!(
        "steady-state: {solve_ms:.3} ms  ({:.1} k LPs/s)",
        batch as f64 / solve_ms
    );
    println!(
        "timing split: pack {:.3} ms | transfer {:.3} ms | execute {:.3} ms | unpack {:.3} ms (mem {:.1}%)",
        timing.pack_ns as f64 / 1e6,
        timing.transfer_ns as f64 / 1e6,
        timing.execute_ns as f64 / 1e6,
        timing.unpack_ns as f64 / 1e6,
        100.0 * timing.memory_fraction()
    );
    println!("optimal: {}  infeasible: {infeasible}", solutions.len() - infeasible);
    Ok(())
}

fn cmd_serve(flags: &Flags) -> anyhow::Result<()> {
    let requests = flag(flags, "requests", 6_000usize);
    let rate = flag(flags, "rate", 2_000.0f64);
    let max_wait_ms = flag(flags, "max-wait-ms", 2u64);
    let slo_ms = flag(flags, "slo-ms", max_wait_ms);
    let bulk_slo_ms = flag(flags, "bulk-slo-ms", slo_ms * 8);
    let seed = flag(flags, "seed", 7u64);
    let shards = flag(flags, "shards", 1usize);
    let depth = flag(flags, "depth", 2usize);
    let max_queue = flag(flags, "max-queue", 32_768usize);
    let policy = match flags.get("policy") {
        Some(p) => batch_lp2d::coordinator::ClosePolicy::parse(p)?,
        None => batch_lp2d::coordinator::ClosePolicy::Adaptive,
    };
    let backends = match flags.get("backends") {
        Some(list) => BackendSpec::parse_list(list)?,
        None => Vec::new(),
    };
    let tune_profile = flags.get("tune-profile").map(std::path::PathBuf::from);
    let class_overrides = match flags.get("class-overrides") {
        Some(s) => batch_lp2d::coordinator::ClassOverride::parse_list(s)?,
        None => Vec::new(),
    };
    let capture_path = flags.get("capture").map(std::path::PathBuf::from);
    let capture_sample = flag(flags, "capture-sample", 1u64);
    anyhow::ensure!(capture_sample >= 1, "--capture-sample must be >= 1");
    let capture = capture_path.as_ref().map(|_| TraceCapture::with_sample(capture_sample));
    let spans_out = flags.get("spans-out").map(std::path::PathBuf::from);
    let span_sample = flag(flags, "span-sample", 1u64);
    anyhow::ensure!(span_sample >= 1, "--span-sample must be >= 1");
    let spans = spans_out.as_ref().map(|_| SpanRecorder::new(65_536, span_sample));
    let metrics_out = flags.get("metrics-out").map(std::path::PathBuf::from);
    let tui = flags.contains_key("tui");
    let tui_frame = flags.contains_key("tui-frame");
    let cache_capacity = flag(flags, "cache-capacity", 0usize);
    let cache_eps = flag(flags, "cache-eps", 0.0f64);
    let warm_start = flags.contains_key("warm-start");
    let replay_speed = flag(flags, "replay-speed", 1.0f64);
    anyhow::ensure!(
        replay_speed > 0.0 && replay_speed.is_finite(),
        "--replay-speed must be positive"
    );
    let passes = flag(flags, "passes", 1usize);
    anyhow::ensure!(passes >= 1, "--passes must be >= 1");

    let config = Config {
        max_wait: std::time::Duration::from_millis(slo_ms),
        bulk_wait: std::time::Duration::from_millis(bulk_slo_ms),
        policy,
        max_queue,
        executors: shards.max(1),
        backends,
        depth: PipelineDepth::new(depth),
        tune_profile,
        class_overrides,
        capture: capture.clone(),
        spans: spans.clone(),
        cache_capacity,
        cache_eps,
        warm_start,
        ..Config::default()
    };
    let service = Service::start(artifact_dir(flags), config)?;

    // Live dashboard: a refresher thread over the shared metrics handle,
    // stopped (and joined) before the plain-text report prints.
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let tui_thread = if tui {
        let metrics = service.metrics_shared();
        let names = service.shard_backends().to_vec();
        let stop = stop.clone();
        Some(std::thread::spawn(move || {
            use std::io::Write as _;
            let t0 = std::time::Instant::now();
            // Keep ~16 s of 250 ms samples so the trend sparklines have a
            // window to draw deltas over.
            let mut history = SnapshotRing::new(64);
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                let snap = metrics.snapshot();
                history.push(snap.clone());
                let frame = render_frame_with_history(
                    &snap,
                    &names,
                    t0.elapsed().as_secs_f64(),
                    &history,
                );
                print!("{CLEAR}{frame}");
                let _ = std::io::stdout().flush();
                std::thread::sleep(std::time::Duration::from_millis(250));
            }
        }))
    } else {
        None
    };

    // Traffic: a named scenario (mixed deadline classes, or a trace:PATH
    // replay), or the classic interactive-only Poisson trace.
    let mut rng = Rng::new(seed);
    let reqs: Vec<gen::scenarios::ScenarioRequest> = match flags.get("scenario") {
        Some(name) => gen::scenarios::Scenario::parse(name)?
            .generate_at_speed(&mut rng, requests, rate, replay_speed)?,
        None => {
            let tp = trace::TraceParams { rate, m_lo: 8, m_hi: 64, infeasible_frac: 0.02 };
            trace::poisson_trace(&mut rng, requests, tp)
                .into_iter()
                .map(|r| gen::scenarios::ScenarioRequest {
                    at_ns: r.at_ns,
                    problem: r.problem,
                    class: batch_lp2d::coordinator::DeadlineClass::Interactive,
                })
                .collect()
        }
    };

    println!(
        "serving {requests} requests at ~{rate:.0}/s (open loop, policy {}{})...",
        policy.as_str(),
        if passes > 1 { format!(", {passes} passes") } else { String::new() }
    );
    let t_run = Timer::start();
    let mut infeasible = 0usize;
    let mut shed = 0usize;
    // `--passes N`: replay the same request stream N times through the one
    // service, draining each pass before the next — with the result cache
    // enabled, every repeat pass re-asks exactly the questions the first
    // pass answered (the cache-reuse demonstration, and the CI reuse leg).
    for _ in 0..passes {
        let t0 = Timer::start();
        let mut tickets = Vec::with_capacity(reqs.len());
        for r in &reqs {
            // Open-loop pacing.
            while t0.elapsed_ns() < r.at_ns {
                std::hint::spin_loop();
            }
            tickets.push(
                service
                    .submit_with_class(r.problem.clone(), r.class)
                    .map_err(|e| anyhow::anyhow!("{e}"))?,
            );
        }
        for t in tickets {
            match t.wait() {
                Ok(sol) => {
                    if sol.status == Status::Infeasible {
                        infeasible += 1;
                    }
                }
                // Shed replies are expected under overload with a bounded
                // queue; anything else would double-count in the metrics.
                Err(_) => shed += 1,
            }
        }
    }
    let wall_s = t_run.elapsed_ns() as f64 / 1e9;
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    if let Some(handle) = tui_thread {
        let _ = handle.join();
    }
    let snap = service.metrics().snapshot();
    if tui_frame {
        let names = service.shard_backends().to_vec();
        println!("{}", render_frame(&snap, &names, wall_s));
    }
    println!(
        "done in {wall_s:.2}s -> {:.0} solved LPs/s",
        (requests * passes - shed) as f64 / wall_s
    );
    println!(
        "batches: {}  mean occupancy: {:.1}%  infeasible: {infeasible}",
        snap.batches,
        100.0 * snap.mean_occupancy
    );
    println!(
        "queue wait p50/p95/p99: {:.2}/{:.2}/{:.2} ms   batch exec p50/p95/p99: \
         {:.2}/{:.2}/{:.2} ms",
        snap.queue_wait_p50_ns as f64 / 1e6,
        snap.queue_wait_p95_ns as f64 / 1e6,
        snap.queue_wait_p99_ns as f64 / 1e6,
        snap.exec_p50_ns as f64 / 1e6,
        snap.exec_p95_ns as f64 / 1e6,
        snap.exec_p99_ns as f64 / 1e6
    );
    println!(
        "closes: {} full / {} deadline / {} idle / {} cost / {} flush   \
         shed: {} ({} interactive, {} bulk)",
        snap.closes.full,
        snap.closes.deadline,
        snap.closes.idle,
        snap.closes.cost,
        snap.closes.flush,
        snap.shed(),
        snap.shed_interactive,
        snap.shed_bulk
    );
    for b in &snap.burn {
        let slo_ms =
            if b.slo_ns == u64::MAX { f64::INFINITY } else { b.slo_ns as f64 / 1e6 };
        println!(
            "slo m={} {}: bound {:.2} ms  burn short {:.3} / long {:.3}  \
             violated {}/{} ({:.1}%)",
            b.class_m,
            b.deadline_class.as_str(),
            slo_ms,
            b.short_burn,
            b.long_burn,
            b.violated,
            b.observed,
            100.0 * b.lifetime_burn()
        );
    }
    for p in &snap.padding {
        println!(
            "class m={}: {} batches  padding waste {:.1}%",
            p.class_m,
            p.batches,
            100.0 * p.waste()
        );
    }
    if cache_capacity > 0 {
        println!(
            "cache: {} hits / {} misses / {} evictions  hit-rate {:.1}%  warm-start {}",
            snap.cache_hits,
            snap.cache_misses,
            snap.cache_evictions,
            100.0 * snap.cache_hit_rate(),
            if warm_start { "on" } else { "off" }
        );
    }
    println!("exec memory fraction: {:.1}%", 100.0 * snap.memory_fraction());
    println!("pipeline depth: {}  steals: {}", snap.pipeline_depth, snap.steals());
    let names = service.shard_backends().to_vec();
    for (s, load) in snap.per_shard.iter().enumerate() {
        println!(
            "shard {s} [{}] w={:.1} cal={:.1}: {} batches ({} dispatched)  {} LPs  \
             busy {:.3} ms  steals {}",
            names.get(s).copied().unwrap_or("?"),
            load.weight,
            load.calibrated_weight,
            load.batches,
            load.dispatched,
            load.solved,
            load.busy_ns as f64 / 1e6,
            load.steals
        );
    }
    service.shutdown();
    if let (Some(cap), Some(path)) = (&capture, &capture_path) {
        cap.save(path)?;
        println!(
            "captured {} request(s) -> {} (schema v{TRACE_SCHEMA}; 1-in-{} sampled; \
             replay with --scenario trace:{})",
            cap.len(),
            path.display(),
            cap.sample_every(),
            path.display()
        );
    }
    if let (Some(rec), Some(path)) = (&spans, &spans_out) {
        write_chrome_trace(path, rec)?;
        println!(
            "spans: {} event(s) (1-in-{} sampled, {} dropped at capacity) -> {} \
             (open in ui.perfetto.dev or chrome://tracing)",
            rec.len(),
            rec.sample_every(),
            rec.dropped(),
            path.display()
        );
    }
    if let Some(path) = &metrics_out {
        let shard_names: Vec<String> = names.iter().map(|n| n.to_string()).collect();
        write_metrics_exposition(path, &snap, &shard_names)?;
        println!("metrics: Prometheus text exposition -> {}", path.display());
    }
    Ok(())
}

fn cmd_tune(flags: &Flags) -> anyhow::Result<()> {
    use batch_lp2d::runtime::Manifest;
    use batch_lp2d::tune;

    let out = flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| "TUNE_profile.json".to_string());
    let variant = match flags.get("variant") {
        Some(v) => Variant::parse(v)?,
        None => Variant::Rgb,
    };
    let opts = tune::ProfilerOpts {
        runs: flag(flags, "runs", 3usize),
        warmup: flag(flags, "warmup", 1usize),
        max_batch: flag(flags, "max-batch", 512usize),
        seed: flag(flags, "seed", 0x7E57u64),
    };
    let specs = match flags.get("backends") {
        Some(list) => BackendSpec::parse_list(list)?,
        None => vec![
            BackendSpec::Cpu,
            BackendSpec::BatchCpu { threads: batch_cpu::default_threads() },
            BackendSpec::SimdCpu { threads: batch_cpu::default_threads() },
            BackendSpec::SimdCpuF32 { threads: batch_cpu::default_threads() },
        ],
    };
    anyhow::ensure!(!specs.is_empty(), "no backends to profile");

    // The service's manifest fallback: engine-free mixes profile against
    // the synthetic CPU inventory, no artifacts needed.
    let dir = std::path::PathBuf::from(artifact_dir(flags));
    let needs_engine = specs.iter().any(|s| matches!(s, BackendSpec::Engine));
    let manifest = Manifest::load_or_cpu_fallback(&dir, needs_engine)?;

    // Profile each DISTINCT backend kind once (profiles are keyed by
    // kind, so five identical shards share one calibration).
    let keys = BackendSpec::distinct_keys(&specs);
    println!(
        "tune: profiling {} backend kind(s) over the {} grid ({} runs/point, \
         batches <= {})...",
        keys.len(),
        variant.as_str(),
        opts.runs,
        opts.max_batch
    );
    let mut profile = tune::Profile::default();
    let mut table = batch_lp2d::util::Table::new(&[
        "backend",
        "class_m",
        "setup_ns",
        "per_problem_ns",
        "nominal_weight",
        "calibrated_weight",
    ]);
    for key in &keys {
        let spec = BackendSpec::parse(key)?;
        let mut backend = spec.build(&dir)?;
        let t = Timer::start();
        let fit = tune::profile_backend(backend.as_mut(), key, &manifest, variant, &opts)?;
        println!("  {key}: {} class(es) fitted in {:.1} ms", fit.classes.len(), t.elapsed_ms());
        for c in &fit.classes {
            table.push_row(vec![
                key.clone(),
                c.class_m.to_string(),
                format!("{:.0}", c.setup_ns),
                format!("{:.1}", c.per_problem_ns),
                format!("{:.2}", spec.nominal_weight()),
                format!("{:.2}", c.calibrated_weight()),
            ]);
        }
        profile.upsert(fit);
    }
    println!("\n{}", table.to_markdown());
    for b in &profile.backends {
        let nominal = BackendSpec::parse(&b.backend)?.nominal_weight();
        let calibrated = b.calibrated_weight().unwrap_or(nominal);
        println!(
            "backend {}: nominal weight {:.2} -> calibrated {:.2} ({:+.0}%)",
            b.backend,
            nominal,
            calibrated,
            100.0 * (calibrated / nominal.max(1e-9) - 1.0)
        );
    }
    profile.save_merged(std::path::Path::new(&out))?;
    println!(
        "wrote {out} (schema v{}, idempotent merge; serve with --tune-profile {out})",
        tune::TUNE_SCHEMA
    );
    Ok(())
}

fn cmd_crowd(flags: &Flags) -> anyhow::Result<()> {
    let agents = flag(flags, "agents", 512usize);
    let steps = flag(flags, "steps", 100usize);
    let seed = flag(flags, "seed", 42u64);
    let backend_name = flags.get("backend").cloned().unwrap_or_else(|| "engine".into());

    let mut rng = Rng::new(seed);
    let mut world = World::crossing_groups(&mut rng, agents, WorldParams::default());

    let engine;
    let backend = match backend_name.as_str() {
        "engine" => {
            engine = Engine::new(artifact_dir(flags))?;
            Backend::Engine { engine: &engine, variant: Variant::Rgb }
        }
        "cpu" => Backend::Cpu { algo: Algo::Seidel, threads: batch_cpu::default_threads() },
        other => anyhow::bail!("unknown backend '{other}' (engine|cpu)"),
    };

    println!("crowd: {agents} agents, {steps} steps, backend={backend_name}");
    let t0 = Timer::start();
    let mut total_lps = 0usize;
    let mut total_infeasible = 0usize;
    for step in 0..steps {
        let st = world.step(&backend, &mut rng)?;
        total_lps += st.lps;
        total_infeasible += st.infeasible;
        if step % 20 == 0 {
            println!(
                "  step {step:>4}: mean_m={:.1} solve={:.2} ms arrived={} goal_dist={:.2}",
                st.mean_m,
                st.solve_ns as f64 / 1e6,
                st.arrived,
                world.mean_goal_distance()
            );
        }
    }
    let wall_s = t0.elapsed_ns() as f64 / 1e9;
    println!(
        "done: {:.2}s wall, {:.1} steps/s, {:.0} LPs/s, infeasible {total_infeasible}",
        wall_s,
        steps as f64 / wall_s,
        total_lps as f64 / wall_s
    );
    Ok(())
}

fn cmd_figures(flags: &Flags) -> anyhow::Result<()> {
    if flags.contains_key("fast") {
        std::env::set_var("BATCH_LP2D_BENCH_FAST", "1");
    }
    let which = flags.get("fig").cloned().unwrap_or_else(|| "all".to_string());

    let emit = |name: &str, table: batch_lp2d::util::Table| {
        println!("\n## Figure {name}\n");
        print!("{}", table.to_markdown());
    };

    // Engine-free table: the loadgen companion serves on the CPU-only
    // shard mix, so it must not require artifacts (and `--fig loadgen`
    // works on hosts where Engine::new would fail).
    if which == "loadgen" {
        emit(
            "L (latency under load, loadgen companion)",
            figures::fig_loadgen(std::path::Path::new(&artifact_dir(flags)), 3_000)?,
        );
        return Ok(());
    }

    // Engine-free table: pure CPU backends, so the SoA-vs-scalar kernel
    // comparison runs on any host (like the simd CI leg).
    if which == "simd" {
        emit(
            "V (simd-cpu vs scalar CPU backends)",
            figures::fig_simd(batch_cpu::default_threads(), 3)?,
        );
        return Ok(());
    }

    let engine = Engine::new(artifact_dir(flags))?;
    let ctx = FigureCtx::new(&engine);

    let all = which == "all";
    if all || which == "imbalance" {
        emit("1/2 (imbalance)", imbalance::imbalance_table(3, &[16, 64, 256], 8));
    }
    if all || which == "3a" {
        emit("3a (time vs size, batch 128)", figures::fig3(&ctx, 128, figures::SIZES));
    }
    if all || which == "3b" {
        emit("3b (time vs size, batch 2048)", figures::fig3(&ctx, 2048, figures::SIZES));
    }
    if all || which == "3c" {
        emit("3c (time vs size, batch 4096)", figures::fig3(&ctx, 4096, figures::SIZES));
    }
    if all || which == "4a" {
        emit("4a (time vs batch, m 64)", figures::fig4(&ctx, 64, figures::BATCHES));
    }
    if all || which == "4b" {
        emit("4b (time vs batch, m 256)", figures::fig4(&ctx, 256, figures::BATCHES));
    }
    if all || which == "5" {
        emit(
            "5 (memory fraction)",
            figures::fig5(&ctx, &[128, 512, 2048], &[16, 64, 256])?,
        );
    }
    if all || which == "7a" {
        emit("7a (naive vs rgb, batch 1024)", figures::fig7(&ctx, 1024, figures::SIZES)?);
    }
    if all || which == "7b" {
        emit("7b (naive vs rgb, batch 4096)", figures::fig7(&ctx, 4096, figures::SIZES)?);
    }
    if all || which == "shards" {
        // fig_shard_sweep builds its own engines (one per shard).
        emit(
            "S (shard-count sweep)",
            figures::fig_shard_sweep(
                std::path::Path::new(&artifact_dir(flags)),
                2048,
                64,
                &[1, 2, 4],
            )?,
        );
    }
    if all || which == "depth" {
        // fig_depth_sweep builds its own 2-engine sharded setup per depth.
        emit(
            "D (pipeline-depth sweep)",
            figures::fig_depth_sweep(
                std::path::Path::new(&artifact_dir(flags)),
                2048,
                64,
                &[2, 3, 4],
            )?,
        );
    }
    if all {
        // Also reachable engine-free via `--fig loadgen` / `--fig simd`
        // (early returns above); under `all` they ride along with the
        // engine figures.
        emit(
            "L (latency under load, loadgen companion)",
            figures::fig_loadgen(std::path::Path::new(&artifact_dir(flags)), 3_000)?,
        );
        emit(
            "V (simd-cpu vs scalar CPU backends)",
            figures::fig_simd(batch_cpu::default_threads(), 3)?,
        );
    }
    Ok(())
}

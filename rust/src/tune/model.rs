//! The cost-model seam: one trait ([`CostModel`]) answering every
//! "what does work cost where" question the system asks — weighted
//! estimated-finish dispatch (shard + coordinator), the admission layer's
//! cost-aware close, and the chunk-sizing policy — with two
//! implementations behind it:
//!
//! * [`NominalModel`] — the pre-calibration behaviour, verbatim: weights
//!   from [`Backend::capacity_weight`], costs from [`Backend::cost_ns`]
//!   evaluated over the bucket inventory. Constructing a service or a
//!   sharded run without a profile goes through this path and is
//!   bit-for-bit the old code.
//! * [`CalibratedModel`] — a loaded [`Profile`]'s fitted
//!   `setup_ns + per_problem_ns` models, consulted per (shard, class),
//!   continuously sharpened by the online [`Refiner`] from live
//!   `ExecTiming` observations. Estimate priority per cell: refined EWMA,
//!   then the offline fit, then the nominal constants — so a partial
//!   profile degrades gracefully instead of starving unprofiled shards.
//!
//! Like the refiner (and the admission pipeline), the calibrated model
//! **reads no clock**: staleness checks use the newest timestamp the
//! caller passed to [`CalibratedModel::observe`].

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::runtime::backend::{build_cost_table, Backend};
use crate::runtime::manifest::{Bucket, Manifest, Variant};
use crate::tune::profile::{nominal_per_problem_ns, BackendFit, Profile};
use crate::tune::refine::Refiner;

/// Sentinel cost for bucket shapes a model knows nothing about: large
/// enough that dispatch shuns them, small enough not to overflow sums
/// (mirrors `batch_ests_ns`).
pub const UNKNOWN_COST_NS: u64 = u64::MAX / 2;

/// Everything the dispatch, admission, and chunking layers ask about
/// execution cost, behind one seam.
pub trait CostModel: Send + Sync {
    /// Number of shards the model covers.
    fn shards(&self) -> usize;

    /// Relative capacity weight of one shard (the dispatch bias; 1.0 =
    /// one nominal CPU worker).
    fn weight(&self, shard: usize) -> f64;

    /// Estimated busy-ns for `shard` to execute one `bucket`-shaped batch.
    fn bucket_cost_ns(&self, shard: usize, bucket: &Bucket) -> u64;

    /// Fitted `(setup_ns, per_problem_ns)` terms of a (shard, class) cell
    /// for amortization-aware chunk sizing; `None` when uncalibrated.
    fn chunk_terms(&self, shard: usize, class_m: usize) -> Option<(f64, f64)>;

    /// Estimated busy-ns for `shard` to run a batch of `used` occupied
    /// slots in `bucket`. Default: the bucket cost scaled by occupancy
    /// (the pre-seam behaviour); calibrated implementations apply their
    /// fitted setup/marginal split instead, so the per-batch setup is
    /// never scaled away on sparse batches.
    fn batch_est_ns(&self, shard: usize, bucket: &Bucket, used: usize) -> u64 {
        crate::runtime::backend::scale_cost_ns(
            self.bucket_cost_ns(shard, bucket),
            used,
            bucket.batch,
        )
    }
}

/// Evaluate a model over a variant's bucket inventory, in the same
/// `table[shard][(batch, m)]` shape as
/// [`build_cost_table`](crate::runtime::backend::build_cost_table) —
/// what the steal queues' pending-estimate accounting consumes.
pub fn model_cost_table(
    model: &dyn CostModel,
    manifest: &Manifest,
    variant: Variant,
) -> Vec<HashMap<(usize, usize), u64>> {
    (0..model.shards())
        .map(|s| {
            manifest
                .of_variant(variant)
                .into_iter()
                .map(|bk| ((bk.batch, bk.m), model.bucket_cost_ns(s, bk)))
                .collect()
        })
        .collect()
}

/// All shard weights of a model, in shard order.
pub fn model_weights(model: &dyn CostModel) -> Vec<f64> {
    (0..model.shards()).map(|s| model.weight(s)).collect()
}

/// The uncalibrated seam implementation: nominal constants, precomputed
/// over the bucket inventory exactly like the pre-seam dispatch did.
#[derive(Clone, Debug)]
pub struct NominalModel {
    weights: Vec<f64>,
    table: Vec<HashMap<(usize, usize), u64>>,
}

impl NominalModel {
    /// Evaluate every backend's nominal `capacity_weight`/`cost_ns` over
    /// the manifest (the backends move to their shard threads afterwards).
    pub fn from_backends<B: Backend>(
        backends: &[B],
        manifest: &Manifest,
        variant: Variant,
    ) -> NominalModel {
        NominalModel {
            weights: backends.iter().map(|b| b.capacity_weight()).collect(),
            table: build_cost_table(backends, manifest, variant),
        }
    }
}

impl CostModel for NominalModel {
    fn shards(&self) -> usize {
        self.weights.len()
    }

    fn weight(&self, shard: usize) -> f64 {
        self.weights[shard]
    }

    fn bucket_cost_ns(&self, shard: usize, bucket: &Bucket) -> u64 {
        self.table[shard]
            .get(&(bucket.batch, bucket.m))
            .copied()
            .unwrap_or(UNKNOWN_COST_NS)
    }

    fn chunk_terms(&self, _shard: usize, _class_m: usize) -> Option<(f64, f64)> {
        None
    }
}

/// The calibrated seam implementation: offline fits + online refinement
/// over a nominal fallback. Shared via `Arc` between the execute stages
/// (observers) and the dispatcher/metrics (readers).
#[derive(Debug)]
pub struct CalibratedModel {
    nominal: NominalModel,
    /// Distinct size classes of the served variant (ascending).
    classes: Vec<usize>,
    /// Per-shard offline fits (`None` = shard's backend not in the
    /// profile).
    fits: Vec<Option<BackendFit>>,
    refiner: Refiner,
    /// Online refinement only runs when a profile was loaded; the nominal
    /// constructor leaves it off so uncalibrated deployments behave
    /// exactly as before.
    refine: bool,
    /// Per-shard [`Backend::executes_padding`] flags: a lockstep shard
    /// pays its calibrated per-slot rate on every bucket slot, padded or
    /// not, so occupancy-sensitive estimates must not scale its cost
    /// down on sparse batches. Empty = all occupancy-proportional (the
    /// CPU default).
    lockstep: Vec<bool>,
    /// Newest timestamp seen by `observe` — the injected clock the
    /// staleness checks read (the model itself never reads wall time).
    last_now: Mutex<Option<Instant>>,
}

impl CalibratedModel {
    /// Wrap a nominal model with calibration disabled: behaves exactly
    /// like [`NominalModel`], observation calls are no-ops.
    pub fn nominal(nominal: NominalModel, manifest: &Manifest, variant: Variant) -> Self {
        let shards = nominal.shards();
        CalibratedModel {
            nominal,
            classes: manifest.classes(variant),
            fits: vec![None; shards],
            refiner: Refiner::default(),
            refine: false,
            lockstep: Vec::new(),
            last_now: Mutex::new(None),
        }
    }

    /// Bind a loaded profile to a shard set: `keys[s]` is shard `s`'s
    /// backend key (its [`BackendSpec::key`](crate::coordinator::BackendSpec)),
    /// matched against the profile's fitted backends. Shards without a
    /// matching fit stay nominal.
    pub fn from_profile(
        profile: &Profile,
        keys: &[String],
        nominal: NominalModel,
        manifest: &Manifest,
        variant: Variant,
    ) -> Self {
        assert_eq!(keys.len(), nominal.shards(), "one key per shard");
        // Variant-scoped lookup: a fit measured on another kernel family
        // never leaks into this deployment's cost model.
        let fits = keys.iter().map(|k| profile.backend(k, variant).cloned()).collect();
        CalibratedModel {
            nominal,
            classes: manifest.classes(variant),
            fits,
            refiner: Refiner::default(),
            refine: true,
            lockstep: Vec::new(),
            last_now: Mutex::new(None),
        }
    }

    /// Record which shards run lockstep devices ([`Backend::executes_padding`]):
    /// their occupancy-sensitive batch estimates charge the whole bucket,
    /// matching how their refiner observations are normalized.
    pub fn with_lockstep(mut self, lockstep: Vec<bool>) -> Self {
        assert!(
            lockstep.is_empty() || lockstep.len() == self.nominal.shards(),
            "one lockstep flag per shard"
        );
        self.lockstep = lockstep;
        self
    }

    /// Toggle online refinement: off, a profile-backed model follows the
    /// offline fits verbatim (observations become no-ops); on for a
    /// nominal wrapper, the model calibrates from live traffic alone.
    pub fn with_refine(mut self, refine: bool) -> Self {
        self.refine = refine;
        self
    }

    /// Whether any shard carries calibration (an offline fit, or live
    /// refinement being enabled).
    pub fn is_calibrated(&self) -> bool {
        self.fits.iter().any(|f| f.is_some()) || self.refine
    }

    /// Whether live observations can still move this model's estimates.
    /// `false` means every weight/cost is frozen at its startup value —
    /// callers on hot paths may snapshot once instead of re-reading.
    pub fn is_refining(&self) -> bool {
        self.refine
    }

    /// Nominal weights, for the nominal-vs-calibrated report.
    pub fn nominal_weights(&self) -> Vec<f64> {
        (0..self.nominal.shards()).map(|s| self.nominal.weight(s)).collect()
    }

    /// Fold one completed batch into the online refiner (no-op for a
    /// nominal model). `now` is the caller's clock.
    pub fn observe(
        &self,
        shard: usize,
        class_m: usize,
        used: usize,
        execute_ns: u64,
        now: Instant,
    ) {
        if !self.refine {
            return;
        }
        {
            let mut last = self.last_now.lock().unwrap();
            *last = Some(last.map_or(now, |l| l.max(now)));
        }
        self.refiner.observe(shard, class_m, used, execute_ns, now);
    }

    /// Live refinement observations folded in so far.
    pub fn refined_samples(&self) -> u64 {
        self.refiner.samples()
    }

    fn now(&self) -> Option<Instant> {
        *self.last_now.lock().unwrap()
    }

    /// Best `(setup_ns, per_problem_ns)` estimate for a (shard, class)
    /// cell: refined EWMA first, then the offline fit, then `None`. A
    /// refined estimate reports ZERO setup: the EWMA rate is
    /// `execute_ns / used`, which already amortizes the batch setup at
    /// the observed occupancy — re-adding the fitted `setup_ns` on top
    /// would count it twice and bias estimates against refined shards.
    fn terms(&self, shard: usize, class_m: usize) -> Option<(f64, f64)> {
        let fit = self.fits.get(shard)?.as_ref();
        let fitted = fit.and_then(|f| f.class(class_m));
        if self.refine {
            if let Some(now) = self.now() {
                if let Some(r) = self.refiner.estimate(shard, class_m, now) {
                    return Some((0.0, r.per_problem_ns));
                }
            }
        }
        fitted.map(|c| (c.setup_ns, c.per_problem_ns))
    }
}

impl CostModel for CalibratedModel {
    fn shards(&self) -> usize {
        self.nominal.shards()
    }

    /// Measured relative throughput: mean over the shard's calibrated
    /// classes of `nominal_per_problem / measured_per_problem`, falling
    /// back to the nominal capacity weight for unprofiled shards.
    fn weight(&self, shard: usize) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for &class_m in &self.classes {
            if let Some((_, per)) = self.terms(shard, class_m) {
                sum += nominal_per_problem_ns(class_m) / per.max(1e-9);
                n += 1;
            }
        }
        if n == 0 {
            self.nominal.weight(shard)
        } else {
            sum / n as f64
        }
    }

    fn bucket_cost_ns(&self, shard: usize, bucket: &Bucket) -> u64 {
        match self.terms(shard, bucket.m) {
            Some((setup, per)) => (setup + per * bucket.batch as f64).max(0.0) as u64,
            None => self.nominal.bucket_cost_ns(shard, bucket),
        }
    }

    fn chunk_terms(&self, shard: usize, class_m: usize) -> Option<(f64, f64)> {
        self.terms(shard, class_m)
    }

    /// The fitted split applied directly — `setup + per_problem * slots`
    /// — NOT the whole-bucket cost scaled by occupancy, which would
    /// wrongly shrink the per-batch setup on sparse batches. `slots` is
    /// the batch's occupancy for backends that skip padding, and the
    /// FULL bucket for lockstep devices
    /// ([`CalibratedModel::with_lockstep`]) — a sparse batch costs such
    /// a device the same as a full one, and its refined rates are
    /// normalized per bucket slot to match. Uncalibrated cells fall back
    /// to the occupancy-scaled nominal default.
    fn batch_est_ns(&self, shard: usize, bucket: &Bucket, used: usize) -> u64 {
        let slots = if self.lockstep.get(shard).copied().unwrap_or(false) {
            bucket.batch
        } else {
            used
        };
        match self.terms(shard, bucket.m) {
            Some((setup, per)) => (setup + per * slots as f64).max(0.0) as u64,
            None => crate::runtime::backend::scale_cost_ns(
                self.nominal.bucket_cost_ns(shard, bucket),
                slots,
                bucket.batch,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::{BatchCpuBackend, CpuShardExecutor, NOMINAL_ROW_NS};
    use crate::tune::profile::ClassFit;
    use std::time::Duration;

    fn manifest() -> Manifest {
        Manifest::cpu_fallback()
    }

    fn boxed_backends() -> Vec<Box<dyn Backend>> {
        vec![Box::new(CpuShardExecutor), Box::new(BatchCpuBackend::new(2))]
    }

    fn fit(backend: &str, per_16: f64, per_64: f64) -> BackendFit {
        BackendFit {
            backend: backend.into(),
            variant: Variant::Rgb,
            classes: vec![
                ClassFit { class_m: 16, setup_ns: 100.0, per_problem_ns: per_16, points: 2 },
                ClassFit { class_m: 64, setup_ns: 200.0, per_problem_ns: per_64, points: 2 },
            ],
        }
    }

    #[test]
    fn nominal_model_reproduces_backend_constants() {
        let m = manifest();
        let backends = boxed_backends();
        let model = NominalModel::from_backends(&backends, &m, Variant::Rgb);
        assert_eq!(model.shards(), 2);
        assert_eq!(model.weight(0), 1.0);
        assert_eq!(model.weight(1), 2.0);
        let b = m.fit(Variant::Rgb, 32, 16).unwrap();
        assert_eq!(model.bucket_cost_ns(0, b), backends[0].cost_ns(b));
        assert_eq!(model.bucket_cost_ns(1, b), backends[1].cost_ns(b));
        assert_eq!(model.chunk_terms(0, 16), None);
        // Unknown shapes are shunned, not panicked on.
        let alien = Bucket { batch: 7, m: 7, ..b.clone() };
        assert_eq!(model.bucket_cost_ns(0, &alien), UNKNOWN_COST_NS);
        // model_cost_table matches build_cost_table cell for cell.
        assert_eq!(
            model_cost_table(&model, &m, Variant::Rgb),
            build_cost_table(&backends, &m, Variant::Rgb)
        );
        assert_eq!(model_weights(&model), vec![1.0, 2.0]);
    }

    #[test]
    fn nominal_wrapper_is_transparent_and_ignores_observations() {
        let m = manifest();
        let nominal = NominalModel::from_backends(&boxed_backends(), &m, Variant::Rgb);
        let model = CalibratedModel::nominal(nominal.clone(), &m, Variant::Rgb);
        assert!(!model.is_calibrated());
        model.observe(0, 16, 32, 1, Instant::now());
        assert_eq!(model.refined_samples(), 0);
        assert_eq!(model.weight(0), nominal.weight(0));
        let b = m.fit(Variant::Rgb, 32, 16).unwrap();
        assert_eq!(model.bucket_cost_ns(0, b), nominal.bucket_cost_ns(0, b));
        assert_eq!(model.chunk_terms(1, 64), None);
    }

    #[test]
    fn profile_overrides_nominal_and_skews_weights() {
        let m = manifest();
        // Two nominal weight-1.0 shards; the profile says shard 0's
        // backend measures 4x the throughput of shard 1's.
        let per_slow_16 = 4.0 * (16 * NOMINAL_ROW_NS) as f64;
        let mut profile = Profile::default();
        profile.upsert(fit("batch-cpu:1", per_slow_16 / 4.0, (64 * NOMINAL_ROW_NS) as f64));
        profile.upsert(fit("cpu", per_slow_16, 4.0 * (64 * NOMINAL_ROW_NS) as f64));
        let backends: Vec<Box<dyn Backend>> =
            vec![Box::new(BatchCpuBackend::new(1)), Box::new(CpuShardExecutor)];
        let nominal = NominalModel::from_backends(&backends, &m, Variant::Rgb);
        let model = CalibratedModel::from_profile(
            &profile,
            &["batch-cpu:1".into(), "cpu".into()],
            nominal,
            &m,
            Variant::Rgb,
        );
        assert!(model.is_calibrated());
        assert_eq!(model.nominal_weights(), vec![1.0, 1.0]);
        // Calibrated: shard 0 measures weight 1.0, shard 1 weight 0.25 —
        // a 4x ratio the nominal constants cannot see.
        let w0 = model.weight(0);
        let w1 = model.weight(1);
        assert!((w0 / w1 - 4.0).abs() < 1e-9, "w0={w0} w1={w1}");
        // Costs come from the fits (setup + per * batch), not the table.
        let b = m.fit(Variant::Rgb, 32, 16).unwrap();
        let want0 = (100.0 + (per_slow_16 / 4.0) * 32.0) as u64;
        assert_eq!(model.bucket_cost_ns(0, b), want0);
        assert_eq!(model.chunk_terms(0, 16), Some((100.0, per_slow_16 / 4.0)));
        // Unprofiled class/backend shapes fall back to nominal.
        let alien = Bucket { batch: 7, m: 7, ..b.clone() };
        assert_eq!(model.bucket_cost_ns(0, &alien), UNKNOWN_COST_NS);
    }

    #[test]
    fn partial_profiles_leave_other_shards_nominal() {
        let m = manifest();
        let mut profile = Profile::default();
        profile.upsert(fit("cpu", 100.0, 400.0));
        let backends = boxed_backends(); // [cpu, batch-cpu:2]
        let nominal = NominalModel::from_backends(&backends, &m, Variant::Rgb);
        let model = CalibratedModel::from_profile(
            &profile,
            &["cpu".into(), "batch-cpu:2".into()],
            nominal,
            &m,
            Variant::Rgb,
        );
        // Shard 1's key is not in the profile: nominal weight and costs.
        assert_eq!(model.weight(1), 2.0);
        let b = m.fit(Variant::Rgb, 32, 16).unwrap();
        assert_eq!(model.bucket_cost_ns(1, b), backends[1].cost_ns(b));
        assert!(model.weight(0) > 2.0, "calibrated cpu shard measured fast");
    }

    #[test]
    fn refinement_overrides_fit_and_expires_back_to_it() {
        let m = manifest();
        let mut profile = Profile::default();
        profile.upsert(fit("cpu", 1000.0, 4000.0));
        let nominal = NominalModel::from_backends(
            &[Box::new(CpuShardExecutor) as Box<dyn Backend>],
            &m,
            Variant::Rgb,
        );
        let model =
            CalibratedModel::from_profile(&profile, &["cpu".into()], nominal, &m, Variant::Rgb);
        let b = m.fit(Variant::Rgb, 32, 16).unwrap();
        // Before any observation: the offline fit.
        assert_eq!(model.bucket_cost_ns(0, b), (100.0 + 1000.0 * 32.0) as u64);
        // Live batches measure 2000ns/problem: the refined EWMA (seeded
        // at the first sample) takes over. Setup drops to zero — the
        // observed per-problem rate already amortizes it.
        let t0 = Instant::now();
        model.observe(0, 16, 10, 20_000, t0);
        assert_eq!(model.refined_samples(), 1);
        assert_eq!(model.bucket_cost_ns(0, b), (2000.0 * 32.0) as u64);
        assert_eq!(model.chunk_terms(0, 16), Some((0.0, 2000.0)));
        // The refined estimate goes stale (max_age exceeded at the newest
        // observed timestamp): back to the offline fit.
        model.observe(0, 64, 1, 4000, t0 + Duration::from_secs(301));
        assert_eq!(model.bucket_cost_ns(0, b), (100.0 + 1000.0 * 32.0) as u64);
    }
}

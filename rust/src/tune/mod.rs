//! Calibration subsystem: measured backend cost models replacing the
//! nominal `capacity_weight`/`cost_ns` constants everywhere dispatch,
//! admission, and chunking decisions are made.
//!
//! Three parts:
//!
//! * [`profile`] — the **offline profiler**: runs a backend over the
//!   (batch size × constraint class) grid of its variant's bucket
//!   inventory and fits a per-class linear cost model
//!   (`setup_ns + per_problem_ns * n`), persisted to the schema-versioned
//!   `TUNE_profile.json` (idempotent merge, like `BENCH_pipeline.json`).
//!   Driven by the CLI's `tune` subcommand and the `calibration` bench
//!   (which also emits the predicted-vs-measured accuracy table).
//! * [`model`] — the **seam**: the [`CostModel`] trait behind which
//!   [`NominalModel`] (the old constants, verbatim) and
//!   [`CalibratedModel`] (loaded profile + online refinement) are
//!   interchangeable. `ShardedEngine` and the coordinator's weighted
//!   estimated-finish dispatch read capacity weights from it, the
//!   admission layer's cost-aware close reads per-class batch costs from
//!   it, and the chunk policy reads the fitted setup/marginal split from
//!   it.
//! * [`refine`] — the **online refiner**: per-(shard, class) EWMA over
//!   live per-batch `ExecTiming`, with caller-injected clocks (no wall
//!   time reads — the admission layer's mock-clock testing contract) and
//!   a staleness window that falls back to the offline fit.
//!
//! Deployment flow: `batch-lp2d tune --backends <mix>` writes
//! `TUNE_profile.json`; `serve --tune-profile TUNE_profile.json` (CLI,
//! example, and `coordinator::Config::tune_profile`) loads it, after which
//! `Snapshot::per_shard` reports nominal-vs-calibrated weight pairs and
//! dispatch follows the measured ratios. This is the dispatch foundation
//! real multi-GPU PJRT shards plug into: profile each device ordinal once,
//! and heterogeneous splits track hardware instead of guesses.

pub mod model;
pub mod profile;
pub mod refine;

pub use model::{model_cost_table, model_weights, CalibratedModel, CostModel, NominalModel};
pub use profile::{
    fit_linear, lane_width_for_key, nominal_per_problem_ns, profile_backend, validate_fit,
    AccuracyRow, BackendFit, ClassFit, Observation, Profile, ProfilerOpts, TUNE_SCHEMA,
};
pub use refine::{Refined, Refiner, REFINE_EWMA_ALPHA, REFINE_MAX_AGE};

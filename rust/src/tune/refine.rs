//! The online refiner: per-(shard, class) EWMA of measured per-problem
//! cost, updated from live [`ExecTiming`](crate::runtime::ExecTiming)
//! observations as batches complete.
//!
//! # The injected-clock contract
//!
//! Like the admission pipeline, the refiner **never reads a wall clock**:
//! every observation carries its own timestamp from the caller. That keeps
//! every decision — including the staleness window below — unit-testable
//! with a mock clock (the same contract as admission's no-spin tests).
//!
//! # Staleness
//!
//! A cell that has not seen traffic for [`Refiner::max_age`] reports
//! `None` again: a calibration learned under one load mix must not silently
//! steer dispatch hours later. The profile's offline fit remains the
//! fallback underneath ([`crate::tune::CalibratedModel`] consults the
//! refiner first, then the fitted profile, then the nominal constants).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Default smoothing factor: one observation moves the estimate a quarter
/// of the way (matches the admission layer's arrival-gap EWMA).
pub const REFINE_EWMA_ALPHA: f64 = 0.25;

/// Default staleness window after which a cell's estimate expires.
pub const REFINE_MAX_AGE: Duration = Duration::from_secs(300);

#[derive(Clone, Copy, Debug)]
struct Cell {
    per_problem_ns: f64,
    samples: u64,
    last: Instant,
}

/// One refined estimate, as reported to callers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Refined {
    pub per_problem_ns: f64,
    pub samples: u64,
}

/// Thread-safe per-(shard, class) EWMA store. Shared behind an `Arc` by
/// the execute stages (writers) and the dispatch/metrics readers.
#[derive(Debug)]
pub struct Refiner {
    alpha: f64,
    max_age: Duration,
    cells: Mutex<HashMap<(usize, usize), Cell>>,
}

impl Default for Refiner {
    fn default() -> Self {
        Refiner::new(REFINE_EWMA_ALPHA, REFINE_MAX_AGE)
    }
}

impl Refiner {
    pub fn new(alpha: f64, max_age: Duration) -> Refiner {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0, 1]");
        Refiner { alpha, max_age, cells: Mutex::new(HashMap::new()) }
    }

    pub fn max_age(&self) -> Duration {
        self.max_age
    }

    /// Fold one completed batch in: `execute_ns` busy time over `used`
    /// occupied slots of `class_m` on `shard`, observed at `now` (caller's
    /// clock — the refiner reads none). Degenerate measurements — empty
    /// batches, or a zero-ns timing (coarse clocks) — are ignored, and
    /// the rate is floored at 1 ns/problem: seeding a near-zero rate
    /// would fabricate a near-infinite calibrated weight out of clock
    /// noise, the same failure mode `fit_linear` guards the offline path
    /// against.
    pub fn observe(
        &self,
        shard: usize,
        class_m: usize,
        used: usize,
        execute_ns: u64,
        now: Instant,
    ) {
        if used == 0 || execute_ns == 0 {
            return;
        }
        let per = (execute_ns as f64 / used as f64).max(1.0);
        let mut cells = self.cells.lock().unwrap();
        match cells.get_mut(&(shard, class_m)) {
            // A stale cell restarts from the fresh sample instead of
            // averaging against a dead regime.
            Some(c) if now.saturating_duration_since(c.last) <= self.max_age => {
                c.per_problem_ns += self.alpha * (per - c.per_problem_ns);
                c.samples += 1;
                c.last = now;
            }
            _ => {
                cells.insert(
                    (shard, class_m),
                    Cell { per_problem_ns: per, samples: 1, last: now },
                );
            }
        }
    }

    /// The current estimate for a (shard, class) cell, or `None` when the
    /// cell has never been observed or its last observation is older than
    /// the staleness window at `now`.
    pub fn estimate(&self, shard: usize, class_m: usize, now: Instant) -> Option<Refined> {
        let cells = self.cells.lock().unwrap();
        let c = cells.get(&(shard, class_m))?;
        if now.saturating_duration_since(c.last) > self.max_age {
            return None;
        }
        Some(Refined { per_problem_ns: c.per_problem_ns, samples: c.samples })
    }

    /// Live observations folded in across all cells (diagnostics).
    pub fn samples(&self) -> u64 {
        self.cells.lock().unwrap().values().map(|c| c.samples).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mock clock: a fixed origin plus explicit offsets — the tests never
    /// read the wall clock between observations, mirroring the admission
    /// layer's clock contract.
    fn clock() -> impl Fn(u64) -> Instant {
        let t0 = Instant::now();
        move |ms: u64| t0 + Duration::from_millis(ms)
    }

    #[test]
    fn first_observation_seeds_then_ewma_converges() {
        let at = clock();
        let r = Refiner::new(0.25, Duration::from_secs(60));
        assert_eq!(r.estimate(0, 16, at(0)), None);
        // Seed: 10 problems in 10_000ns -> 1000ns/problem.
        r.observe(0, 16, 10, 10_000, at(0));
        let e = r.estimate(0, 16, at(1)).unwrap();
        assert_eq!(e.per_problem_ns, 1000.0);
        assert_eq!(e.samples, 1);
        // A 2000ns/problem batch moves the estimate a quarter of the way.
        r.observe(0, 16, 5, 10_000, at(2));
        let e = r.estimate(0, 16, at(3)).unwrap();
        assert!((e.per_problem_ns - 1250.0).abs() < 1e-9, "{}", e.per_problem_ns);
        assert_eq!(e.samples, 2);
        // Repeated 2000ns observations converge toward 2000.
        for k in 0..50 {
            r.observe(0, 16, 5, 10_000, at(4 + k));
        }
        let e = r.estimate(0, 16, at(60)).unwrap();
        assert!((e.per_problem_ns - 2000.0).abs() < 1.0, "{}", e.per_problem_ns);
    }

    #[test]
    fn cells_are_independent_per_shard_and_class() {
        let at = clock();
        let r = Refiner::default();
        r.observe(0, 16, 1, 1_000, at(0));
        r.observe(1, 16, 1, 9_000, at(0));
        r.observe(0, 64, 1, 4_000, at(0));
        assert_eq!(r.estimate(0, 16, at(1)).unwrap().per_problem_ns, 1_000.0);
        assert_eq!(r.estimate(1, 16, at(1)).unwrap().per_problem_ns, 9_000.0);
        assert_eq!(r.estimate(0, 64, at(1)).unwrap().per_problem_ns, 4_000.0);
        assert_eq!(r.estimate(1, 64, at(1)), None);
        assert_eq!(r.samples(), 3);
    }

    #[test]
    fn stale_cells_expire_and_reseed() {
        let at = clock();
        let r = Refiner::new(0.5, Duration::from_millis(100));
        r.observe(0, 16, 1, 1_000, at(0));
        // Inside the window: alive.
        assert!(r.estimate(0, 16, at(100)).is_some());
        // Beyond it: expired — the dead regime must not steer dispatch.
        assert_eq!(r.estimate(0, 16, at(101)), None);
        // The next observation RESEEDS rather than averaging with the
        // stale value: 0.5 * (1000 + 5000) would be 3000; a reseed is
        // exactly 5000.
        r.observe(0, 16, 1, 5_000, at(300));
        let e = r.estimate(0, 16, at(301)).unwrap();
        assert_eq!(e.per_problem_ns, 5_000.0);
        assert_eq!(e.samples, 1);
    }

    #[test]
    fn degenerate_observations_are_ignored_or_floored() {
        let at = clock();
        let r = Refiner::default();
        // Empty batch: ignored.
        r.observe(0, 16, 0, 1_000, at(0));
        assert_eq!(r.estimate(0, 16, at(0)), None);
        // Zero-ns timing (coarse clock): ignored, never seeds a
        // near-infinite throughput.
        r.observe(0, 16, 8, 0, at(0));
        assert_eq!(r.estimate(0, 16, at(0)), None);
        assert_eq!(r.samples(), 0);
        // Sub-1ns-per-problem rates floor at 1 ns/problem.
        r.observe(0, 16, 1_000_000, 5, at(1));
        assert_eq!(r.estimate(0, 16, at(1)).unwrap().per_problem_ns, 1.0);
    }
}

//! The offline profiler and its persisted artifact (`TUNE_profile.json`).
//!
//! A profile is a set of **fitted per-backend cost models**: for every
//! (backend kind × constraint class) the profiler measures the wall time
//! of executing full packed batches over the class's compiled batch-size
//! grid and fits a line
//!
//! ```text
//!   cost_ns(n problems) = setup_ns + per_problem_ns * n
//! ```
//!
//! — piecewise-linear across classes, linear within one. `setup_ns`
//! captures the per-batch overhead (dispatch, padding rows, kernel
//! launch), `per_problem_ns` the marginal slot cost; the split is what
//! lets the chunk policy reason about amortization and the admission
//! layer about padding cost.
//!
//! Persistence is the same flat-JSON array shape as
//! `BENCH_pipeline.json`, one record per (backend, class), behind a
//! schema-version header record ([`TUNE_SCHEMA`]). [`Profile::save_merged`]
//! merges idempotently: re-profiling one backend replaces exactly its
//! records and leaves every other backend's calibration alone.

use std::path::Path;

use crate::gen;
use crate::runtime::backend::{Backend, NOMINAL_ROW_NS};
use crate::runtime::manifest::{Manifest, Variant};
use crate::runtime::pack;
use crate::util::flatjson::{extract_num, extract_str, render_array, split_flat_objects};
use crate::util::{Rng, Timer};

/// Version of the `TUNE_profile.json` record schema. Bump when the record
/// fields change; [`Profile::parse`] refuses mismatched files rather than
/// silently misreading them.
///
/// Schema 2 added the required `lane_width` field: every record names the
/// SIMD lane width of the kernel it was measured on, so a profile row
/// fitted on the 16-wide f32 kernel can never silently calibrate the
/// 8-wide f64 kernel (or vice versa) after a backend-key edit.
pub const TUNE_SCHEMA: u32 = 2;

/// The SIMD lane width of the kernel a backend key names: 16 for the
/// wire-precision `simd-cpu-f32*` lanes, 8 for the f64 `simd-cpu*` lanes,
/// 1 for every scalar (or per-problem-threaded) backend. Recorded in each
/// tune record and re-derived at parse time — a mismatch means the profile
/// was measured on a different kernel variant than the key now builds, and
/// the load fails loudly instead of driving dispatch with a foreign fit.
pub fn lane_width_for_key(key: &str) -> usize {
    if key.starts_with("simd-cpu-f32") {
        crate::runtime::simd::LANES32
    } else if key.starts_with("simd-cpu") {
        crate::runtime::simd::LANES
    } else {
        1
    }
}

/// Busy-ns the nominal cost model charges one problem of a class
/// ([`NOMINAL_ROW_NS`] per packed constraint row on a weight-1.0 backend)
/// — the scale calibrated weights are expressed against.
pub fn nominal_per_problem_ns(class_m: usize) -> f64 {
    (class_m as u64 * NOMINAL_ROW_NS) as f64
}

/// Fitted linear cost model of one (backend, class) cell.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClassFit {
    pub class_m: usize,
    /// Per-batch overhead (intercept), clamped non-negative.
    pub setup_ns: f64,
    /// Marginal cost per packed problem slot (slope), strictly positive.
    pub per_problem_ns: f64,
    /// Grid points behind the fit.
    pub points: usize,
}

impl ClassFit {
    /// Predicted busy-ns for a batch of `problems` slots of this class.
    pub fn predict_ns(&self, problems: usize) -> u64 {
        (self.setup_ns + self.per_problem_ns * problems as f64).max(0.0) as u64
    }

    /// Measured throughput of this cell relative to the nominal
    /// weight-1.0 backend (> 1.0 = faster than nominal). Marginal rate
    /// only: setup is amortized away at steady state.
    pub fn calibrated_weight(&self) -> f64 {
        nominal_per_problem_ns(self.class_m) / self.per_problem_ns.max(1e-9)
    }
}

/// Every fitted class of one (backend kind × kernel variant) pair. The
/// variant is part of the identity: a cost model measured on one kernel
/// family must never drive dispatch for another.
#[derive(Clone, Debug, PartialEq)]
pub struct BackendFit {
    /// The backend's stable key ([`crate::coordinator::BackendSpec::key`],
    /// e.g. `cpu`, `batch-cpu:2`, `engine`).
    pub backend: String,
    /// The kernel variant the grid ran on.
    pub variant: Variant,
    /// Class fits, ascending by `class_m`.
    pub classes: Vec<ClassFit>,
}

/// One aggregate cost observation from a live serving source — the
/// loadgen harness's per-class execute accounting
/// ([`crate::bench::loadgen::class_observations`]), fed back into the
/// offline grid fit as a second observation stream: `problems` occupied
/// slots of `class_m` cost `busy_ns` of execute-side time across
/// `samples` batch executions. An aggregate cannot separate the intercept,
/// so its per-problem rate folds per-batch setup in — which is exactly
/// the steady-state serving cost the dispatch weights should track.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Observation {
    pub class_m: usize,
    /// Occupied slots behind the observation (not padded capacity).
    pub problems: usize,
    /// Total execute-side busy time attributed to them, nanoseconds.
    pub busy_ns: f64,
    /// Batch executions behind the aggregate — the blend weight, in the
    /// same unit as [`ClassFit::points`] (one batch ≈ one grid point).
    pub samples: usize,
}

impl Observation {
    /// Mean cost per occupied slot (setup amortized in).
    pub fn per_problem_ns(&self) -> f64 {
        self.busy_ns / self.problems.max(1) as f64
    }
}

impl BackendFit {
    pub fn class(&self, class_m: usize) -> Option<&ClassFit> {
        self.classes.iter().find(|c| c.class_m == class_m)
    }

    /// Blend live observations into the fitted classes, sample-count
    /// weighted: a fit backed by `points` grid measurements meeting an
    /// observation backed by `samples` batches moves
    /// `samples / (points + samples)` of the way toward the observed
    /// rate. `setup_ns` stays from the offline fit (aggregates cannot
    /// separate the intercept); classes the grid never profiled are
    /// created from the observation alone via [`fit_linear`]. Empty or
    /// zero-cost observations are dropped, never fitted.
    pub fn absorb(&mut self, observations: &[Observation]) {
        for obs in observations {
            if obs.problems == 0 || !(obs.busy_ns > 0.0) {
                continue;
            }
            let samples = obs.samples.max(1);
            match self.classes.iter_mut().find(|c| c.class_m == obs.class_m) {
                Some(c) => {
                    let n0 = c.points.max(1) as f64;
                    let n1 = samples as f64;
                    c.per_problem_ns = ((c.per_problem_ns * n0 + obs.per_problem_ns() * n1)
                        / (n0 + n1))
                        .max(1e-9);
                    c.points += samples;
                }
                None => {
                    let (setup_ns, per_problem_ns) =
                        fit_linear(&[(obs.problems, obs.busy_ns)]);
                    self.classes.push(ClassFit {
                        class_m: obs.class_m,
                        setup_ns,
                        per_problem_ns,
                        points: samples,
                    });
                    self.classes.sort_by_key(|c| c.class_m);
                }
            }
        }
    }

    /// Mean calibrated weight across the backend's fitted classes (the
    /// scalar dispatch bias; per-class costs stay per-class).
    pub fn calibrated_weight(&self) -> Option<f64> {
        if self.classes.is_empty() {
            return None;
        }
        let sum: f64 = self.classes.iter().map(|c| c.calibrated_weight()).sum();
        Some(sum / self.classes.len() as f64)
    }
}

/// A loaded (or freshly measured) calibration profile.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Profile {
    pub backends: Vec<BackendFit>,
}

impl Profile {
    /// The fit recorded for one (backend key, variant) pair — variants
    /// never cross-match.
    pub fn backend(&self, key: &str, variant: Variant) -> Option<&BackendFit> {
        self.backends.iter().find(|b| b.backend == key && b.variant == variant)
    }

    /// Insert or replace one backend's fits (keyed by (backend, variant)).
    pub fn upsert(&mut self, fit: BackendFit) {
        match self
            .backends
            .iter_mut()
            .find(|b| b.backend == fit.backend && b.variant == fit.variant)
        {
            Some(b) => *b = fit,
            None => self.backends.push(fit),
        }
        self.backends
            .sort_by(|a, b| (&a.backend, a.variant).cmp(&(&b.backend, b.variant)));
    }

    /// Feed live observations into one backend's fit (creating an
    /// observation-only fit when the backend was never grid-profiled) —
    /// the loadgen → profiler bridge.
    pub fn absorb(&mut self, key: &str, variant: Variant, observations: &[Observation]) {
        match self
            .backends
            .iter_mut()
            .find(|b| b.backend == key && b.variant == variant)
        {
            Some(b) => b.absorb(observations),
            None => {
                let mut fit =
                    BackendFit { backend: key.to_string(), variant, classes: Vec::new() };
                fit.absorb(observations);
                if !fit.classes.is_empty() {
                    self.upsert(fit);
                }
            }
        }
    }

    /// Merge another profile in: its backends replace same-keyed ours.
    pub fn merge(&mut self, other: Profile) {
        for fit in other.backends {
            self.upsert(fit);
        }
    }

    /// Parse a `TUNE_profile.json` text. Refuses missing or mismatched
    /// schema headers — a stale profile must fail loudly, not misread.
    pub fn parse(text: &str) -> anyhow::Result<Profile> {
        let objs = split_flat_objects(text);
        let header_schema = objs
            .iter()
            .find_map(|o| extract_num(o, "tune_schema"))
            .ok_or_else(|| anyhow::anyhow!("tune profile has no tune_schema header"))?;
        anyhow::ensure!(
            header_schema as u32 == TUNE_SCHEMA,
            "tune profile schema {} != supported {TUNE_SCHEMA} (re-run the profiler)",
            header_schema
        );
        let mut profile = Profile::default();
        for obj in &objs {
            // Only the header/comment objects lack a backend; any record
            // that names one must be complete — a truncated or mistyped
            // record aborts the load (fail loudly, never silently run a
            // "calibrated" shard on nominal constants).
            let Some(backend) = extract_str(obj, "backend") else {
                continue;
            };
            let Some(class_m) = extract_num(obj, "class_m") else {
                anyhow::bail!("tune record for {backend} lacks class_m");
            };
            let Some(variant) = extract_str(obj, "variant") else {
                anyhow::bail!("tune record for {backend} lacks a variant");
            };
            let variant = Variant::parse(&variant)?;
            let (Some(setup_ns), Some(per_problem_ns)) =
                (extract_num(obj, "setup_ns"), extract_num(obj, "per_problem_ns"))
            else {
                anyhow::bail!("tune record for {backend} lacks setup_ns/per_problem_ns");
            };
            // Kernel-variant guard: the recorded lane width must match the
            // width of the kernel this backend key builds today. A profile
            // measured on the 16-wide f32 lanes must never calibrate the
            // 8-wide f64 kernel (or any other mismatch) — fail the load.
            let expected_lanes = lane_width_for_key(&backend);
            match extract_num(obj, "lane_width") {
                Some(lw) if lw as usize == expected_lanes => {}
                Some(lw) => anyhow::bail!(
                    "tune record for {backend} was measured on a {}-lane kernel but \
                     '{backend}' builds a {expected_lanes}-lane kernel — stale or \
                     cross-variant profile, re-run the profiler",
                    lw as usize
                ),
                None => anyhow::bail!(
                    "tune record for {backend} lacks lane_width \
                     (schema {TUNE_SCHEMA} requires it; re-run the profiler)"
                ),
            }
            let fit = ClassFit {
                class_m: class_m as usize,
                setup_ns: setup_ns.max(0.0),
                per_problem_ns: per_problem_ns.max(1e-9),
                points: extract_num(obj, "points").unwrap_or(0.0) as usize,
            };
            match profile
                .backends
                .iter_mut()
                .find(|b| b.backend == backend && b.variant == variant)
            {
                Some(b) => {
                    b.classes.retain(|c| c.class_m != fit.class_m);
                    b.classes.push(fit);
                }
                None => profile
                    .backends
                    .push(BackendFit { backend, variant, classes: vec![fit] }),
            }
        }
        for b in &mut profile.backends {
            b.classes.sort_by_key(|c| c.class_m);
        }
        profile
            .backends
            .sort_by(|a, b| (&a.backend, a.variant).cmp(&(&b.backend, b.variant)));
        Ok(profile)
    }

    pub fn load(path: &Path) -> anyhow::Result<Profile> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read tune profile {}: {e}", path.display()))?;
        Self::parse(&text)
            .map_err(|e| anyhow::anyhow!("tune profile {}: {e}", path.display()))
    }

    /// Render the schema header + one flat record per (backend, class).
    pub fn render(&self) -> String {
        let mut bodies = vec![format!(
            "{{\n  \"tune_schema\": {TUNE_SCHEMA},\n  \"_comment\": \"Calibrated backend cost \
             models (setup_ns + per_problem_ns per constraint class), measured by the tune \
             profiler. lane_width names the kernel variant each fit ran on (16 = f32 lanes, \
             8 = f64 lanes, 1 = scalar) and is re-checked on load. Refresh with: cargo run \
             --release -- tune --backends <mix> --out TUNE_profile.json (idempotent merge: \
             re-profiling a backend replaces only its records).\"\n}}"
        )];
        for b in &self.backends {
            for c in &b.classes {
                bodies.push(format!(
                    "{{\n  \"backend\": \"{}\",\n  \"variant\": \"{}\",\n  \
                     \"lane_width\": {},\n  \"class_m\": {},\n  \"setup_ns\": {:.1},\n  \
                     \"per_problem_ns\": {:.1},\n  \"points\": {}\n}}",
                    b.backend,
                    b.variant.as_str(),
                    lane_width_for_key(&b.backend),
                    c.class_m,
                    c.setup_ns,
                    c.per_problem_ns,
                    c.points
                ));
            }
        }
        render_array(&bodies)
    }

    /// Write the profile to `path`, merging over whatever is already
    /// there: existing records for other backends survive, same-keyed
    /// records are replaced. Idempotent — saving twice changes nothing.
    pub fn save_merged(&self, path: &Path) -> anyhow::Result<()> {
        let mut merged = match std::fs::read_to_string(path) {
            Ok(text) => Profile::parse(&text)
                .map_err(|e| anyhow::anyhow!("refusing to overwrite {}: {e}", path.display()))?,
            Err(_) => Profile::default(),
        };
        merged.merge(self.clone());
        std::fs::write(path, merged.render())
            .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", path.display()))
    }
}

/// Least-squares line through `(problems, busy_ns)` grid points, clamped
/// to a physical model: non-negative setup, strictly positive marginal
/// cost. A degenerate fit — one point, zero variance, or a noise-induced
/// NON-POSITIVE slope (a larger batch measuring cheaper than a smaller
/// one) — falls back to the pure mean marginal rate rather than clamping
/// the slope toward zero, which would fabricate a near-infinite
/// calibrated throughput out of measurement noise.
pub fn fit_linear(points: &[(usize, f64)]) -> (f64, f64) {
    assert!(!points.is_empty(), "fit_linear on empty grid");
    let n = points.len() as f64;
    let mean_x = points.iter().map(|&(x, _)| x.max(1) as f64).sum::<f64>() / n;
    let mean_y = points.iter().map(|&(_, y)| y).sum::<f64>() / n;
    let mut cov = 0.0;
    let mut var = 0.0;
    for &(x, y) in points {
        let dx = x as f64 - mean_x;
        cov += dx * (y - mean_y);
        var += dx * dx;
    }
    let slope = if var > 0.0 { cov / var } else { 0.0 };
    if slope <= 0.0 {
        return (0.0, (mean_y / mean_x).max(1e-9));
    }
    let setup = (mean_y - slope * mean_x).max(0.0);
    (setup, slope)
}

/// Profiler knobs.
#[derive(Clone, Copy, Debug)]
pub struct ProfilerOpts {
    /// Timed repetitions per grid point (the minimum is kept — least
    /// scheduler noise).
    pub runs: usize,
    /// Untimed warmup executions per grid point (compiles engine buckets).
    pub warmup: usize,
    /// Cap on profiled batch sizes (keeps the grid cheap in CI).
    pub max_batch: usize,
    pub seed: u64,
}

impl Default for ProfilerOpts {
    fn default() -> Self {
        ProfilerOpts { runs: 3, warmup: 1, max_batch: 512, seed: 0x7E57 }
    }
}

/// Measure one backend over the (batch size × constraint class) grid of a
/// variant's bucket inventory and fit its per-class cost models. Problems
/// carry exactly `class_m` constraints (full rows — the bucket-shaped
/// worst case the dispatch estimates are quoted in).
pub fn profile_backend(
    backend: &mut dyn Backend,
    key: &str,
    manifest: &Manifest,
    variant: Variant,
    opts: &ProfilerOpts,
) -> anyhow::Result<BackendFit> {
    let classes = manifest.classes(variant);
    anyhow::ensure!(!classes.is_empty(), "no {} buckets to profile", variant.as_str());

    let mut rng = Rng::new(opts.seed);
    let mut fits = Vec::with_capacity(classes.len());
    for class_m in classes {
        let mut grid: Vec<usize> = manifest
            .of_variant(variant)
            .iter()
            .filter(|b| b.m == class_m)
            .map(|b| b.batch)
            .collect();
        grid.sort_unstable();
        grid.dedup();
        let smallest = grid[0];
        grid.retain(|&b| b <= opts.max_batch);
        if grid.is_empty() {
            grid.push(smallest);
        }
        let mut points = Vec::with_capacity(grid.len());
        for &batch in &grid {
            let ns = measure_point(backend, manifest, variant, batch, class_m, opts, &mut rng)?;
            points.push((batch, ns));
        }
        let (setup_ns, per_problem_ns) = fit_linear(&points);
        fits.push(ClassFit { class_m, setup_ns, per_problem_ns, points: points.len() });
    }
    Ok(BackendFit { backend: key.to_string(), variant, classes: fits })
}

/// One measured (predicted-vs-measured) validation cell for the
/// calibration-accuracy table.
#[derive(Clone, Debug)]
pub struct AccuracyRow {
    pub backend: String,
    pub class_m: usize,
    /// Occupied slots of the validation batch.
    pub problems: usize,
    pub predicted_ns: u64,
    pub measured_ns: u64,
}

impl AccuracyRow {
    /// Signed relative prediction error ((predicted - measured)/measured).
    pub fn rel_err(&self) -> f64 {
        (self.predicted_ns as f64 - self.measured_ns as f64) / self.measured_ns.max(1) as f64
    }
}

/// Re-measure a fitted backend at full and half occupancy of each class's
/// largest profiled batch, comparing the fit's prediction against fresh
/// wall time — the calibration-accuracy table's rows. Half occupancy is
/// deliberately *off* the fitted grid, so the linear interpolation is
/// tested, not just reproduced.
pub fn validate_fit(
    backend: &mut dyn Backend,
    fit: &BackendFit,
    manifest: &Manifest,
    variant: Variant,
    opts: &ProfilerOpts,
) -> anyhow::Result<Vec<AccuracyRow>> {
    let mut rng = Rng::new(opts.seed ^ 0xACC);
    let mut rows = Vec::new();
    for class in &fit.classes {
        let Some(batch) = manifest
            .of_variant(variant)
            .iter()
            .filter(|b| b.m == class.class_m && b.batch <= opts.max_batch)
            .map(|b| b.batch)
            .max()
        else {
            continue;
        };
        for problems in [batch, (batch / 2).max(1)] {
            let measured_ns = measure_used(
                backend, manifest, variant, batch, class.class_m, problems, opts, &mut rng,
            )?;
            rows.push(AccuracyRow {
                backend: fit.backend.clone(),
                class_m: class.class_m,
                problems,
                predicted_ns: class.predict_ns(problems),
                measured_ns: measured_ns as u64,
            });
        }
    }
    Ok(rows)
}

/// Measure a full-occupancy grid point: `batch` problems of `class_m`
/// constraints through `execute_raw`, minimum wall-ns over `opts.runs`.
fn measure_point(
    backend: &mut dyn Backend,
    manifest: &Manifest,
    variant: Variant,
    batch: usize,
    class_m: usize,
    opts: &ProfilerOpts,
    rng: &mut Rng,
) -> anyhow::Result<f64> {
    measure_used(backend, manifest, variant, batch, class_m, batch, opts, rng)
}

fn measure_used(
    backend: &mut dyn Backend,
    manifest: &Manifest,
    variant: Variant,
    batch: usize,
    class_m: usize,
    problems: usize,
    opts: &ProfilerOpts,
    rng: &mut Rng,
) -> anyhow::Result<f64> {
    let bucket = manifest
        .find(variant, batch, class_m)
        .ok_or_else(|| {
            anyhow::anyhow!("no {} bucket (batch={batch}, m={class_m})", variant.as_str())
        })?
        .clone();
    let batch_problems: Vec<_> = (0..problems).map(|_| gen::feasible(rng, class_m)).collect();
    let pb = pack::pack(&batch_problems, bucket.batch, bucket.m, None)?;
    backend.prepare(&bucket)?;
    for _ in 0..opts.warmup {
        backend.execute_raw(&bucket, &pb)?;
    }
    let mut best = f64::INFINITY;
    for _ in 0..opts.runs.max(1) {
        let t = Timer::start();
        backend.execute_raw(&bucket, &pb)?;
        best = best.min(t.elapsed_ns() as f64);
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::{BatchCpuBackend, CpuShardExecutor};

    #[test]
    fn fit_linear_recovers_setup_and_slope() {
        // Exact line: 1000 + 50n.
        let points: Vec<(usize, f64)> =
            [8usize, 32, 128].iter().map(|&n| (n, 1000.0 + 50.0 * n as f64)).collect();
        let (setup, slope) = fit_linear(&points);
        assert!((setup - 1000.0).abs() < 1e-6, "setup {setup}");
        assert!((slope - 50.0).abs() < 1e-9, "slope {slope}");
        // Negative intercepts clamp to zero, slope stays positive.
        let (setup, slope) = fit_linear(&[(10, 10.0), (100, 1000.0)]);
        assert_eq!(setup, 0.0);
        assert!(slope > 0.0);
        // Single point: pure marginal rate.
        let (setup, slope) = fit_linear(&[(10, 500.0)]);
        assert_eq!(setup, 0.0);
        assert!((slope - 50.0).abs() < 1e-9);
        // Noise-induced NEGATIVE slope (bigger batch measured cheaper):
        // falls back to the mean marginal rate instead of clamping toward
        // zero and fabricating a ~1e11x calibrated weight.
        let (setup, slope) = fit_linear(&[(10, 2000.0), (100, 1000.0)]);
        assert_eq!(setup, 0.0);
        let want = (2000.0 + 1000.0) / 2.0 / 55.0; // mean_y / mean_x
        assert!((slope - want).abs() < 1e-9, "slope {slope} want {want}");
        assert!(slope > 1.0, "sane marginal rate, not an epsilon clamp");
    }

    #[test]
    fn class_fit_predicts_and_weights() {
        let fit =
            ClassFit { class_m: 16, setup_ns: 100.0, per_problem_ns: 320.0, points: 2 };
        assert_eq!(fit.predict_ns(10), 3300);
        // Nominal 16-row problem costs 640ns on a weight-1 backend; this
        // one takes 320ns/problem -> calibrated weight 2.0.
        assert!((fit.calibrated_weight() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn profile_render_parse_roundtrip_and_merge() {
        let mut p = Profile::default();
        p.upsert(BackendFit {
            backend: "cpu".into(),
            variant: Variant::Rgb,
            classes: vec![
                ClassFit { class_m: 16, setup_ns: 10.0, per_problem_ns: 600.0, points: 2 },
                ClassFit { class_m: 64, setup_ns: 20.0, per_problem_ns: 2500.0, points: 3 },
            ],
        });
        p.upsert(BackendFit {
            backend: "batch-cpu:2".into(),
            variant: Variant::Rgb,
            classes: vec![ClassFit {
                class_m: 16,
                setup_ns: 40.0,
                per_problem_ns: 330.0,
                points: 2,
            }],
        });
        let parsed = Profile::parse(&p.render()).unwrap();
        assert_eq!(parsed, p);
        // Variant-scoped identity: an rgb fit never answers for simplex.
        assert!(parsed.backend("cpu", Variant::Rgb).is_some());
        assert!(parsed.backend("cpu", Variant::Simplex).is_none());
        // Merge replaces same-keyed backends, keeps the rest.
        let mut update = Profile::default();
        update.upsert(BackendFit {
            backend: "cpu".into(),
            variant: Variant::Rgb,
            classes: vec![ClassFit {
                class_m: 16,
                setup_ns: 0.0,
                per_problem_ns: 500.0,
                points: 4,
            }],
        });
        let mut merged = parsed.clone();
        merged.merge(update);
        assert_eq!(merged.backend("cpu", Variant::Rgb).unwrap().classes.len(), 1);
        assert!(merged.backend("batch-cpu:2", Variant::Rgb).is_some());
    }

    #[test]
    fn absorb_observations_shift_the_fit() {
        let mut fit = BackendFit {
            backend: "simd-cpu:4".into(),
            variant: Variant::Rgb,
            classes: vec![ClassFit {
                class_m: 16,
                setup_ns: 100.0,
                per_problem_ns: 600.0,
                points: 3,
            }],
        };
        // One serving batch measured at 1000 ns/problem against a 3-point
        // grid fit at 600: the blend moves 1/4 of the way.
        fit.absorb(&[Observation { class_m: 16, problems: 10, busy_ns: 10_000.0, samples: 1 }]);
        let c = *fit.class(16).unwrap();
        assert!((c.per_problem_ns - 700.0).abs() < 1e-9, "rate {}", c.per_problem_ns);
        assert_eq!(c.points, 4);
        assert_eq!(c.setup_ns, 100.0, "intercept kept from the offline fit");
        // A heavily sampled serving aggregate dominates the grid fit.
        fit.absorb(&[Observation {
            class_m: 16,
            problems: 1_000,
            busy_ns: 200_000.0,
            samples: 396,
        }]);
        let c = *fit.class(16).unwrap();
        assert!((c.per_problem_ns - 205.0).abs() < 1e-9, "rate {}", c.per_problem_ns);
        // Classes the grid never profiled are created from the
        // observation alone (single-point fit: zero setup, mean rate).
        fit.absorb(&[Observation { class_m: 64, problems: 8, busy_ns: 16_000.0, samples: 2 }]);
        let c64 = *fit.class(64).unwrap();
        assert_eq!(c64.setup_ns, 0.0);
        assert!((c64.per_problem_ns - 2_000.0).abs() < 1e-9);
        assert_eq!(c64.points, 2);
        assert_eq!(fit.classes[0].class_m, 16, "classes stay sorted");
        // Zero-work observations never touch the fit.
        let before = fit.clone();
        fit.absorb(&[Observation { class_m: 16, problems: 0, busy_ns: 0.0, samples: 5 }]);
        assert_eq!(fit, before);

        // Profile-level absorb creates a missing backend fit.
        let mut p = Profile::default();
        p.absorb(
            "cpu",
            Variant::Rgb,
            &[Observation { class_m: 16, problems: 4, busy_ns: 4_000.0, samples: 1 }],
        );
        let created = p.backend("cpu", Variant::Rgb).unwrap().class(16).unwrap();
        assert!((created.per_problem_ns - 1_000.0).abs() < 1e-9);
        // But an all-empty observation set creates nothing.
        p.absorb(
            "engine",
            Variant::Rgb,
            &[Observation { class_m: 16, problems: 0, busy_ns: 0.0, samples: 1 }],
        );
        assert!(p.backend("engine", Variant::Rgb).is_none());
    }

    #[test]
    fn parse_rejects_missing_or_wrong_schema() {
        assert!(Profile::parse("[\n{\n  \"backend\": \"cpu\"\n}\n]").is_err());
        let wrong = "[\n{\n  \"tune_schema\": 999\n}\n]";
        let err = Profile::parse(wrong).unwrap_err().to_string();
        assert!(err.contains("schema"), "{err}");
        // Schema 1 profiles (no lane_width) are stale now — refused at the
        // header, before any record parses.
        let v1 = "[\n{\n  \"tune_schema\": 1\n}\n]";
        let err = Profile::parse(v1).unwrap_err().to_string();
        assert!(err.contains("schema"), "{err}");
        // A record naming a backend but missing fields aborts the load —
        // a truncated profile must never half-apply.
        let bad = "[\n{\n  \"tune_schema\": 2\n},\n{\n  \"backend\": \"cpu\"\n}\n]";
        let err = Profile::parse(bad).unwrap_err().to_string();
        assert!(err.contains("class_m"), "{err}");
    }

    #[test]
    fn lane_width_is_derived_from_the_backend_key() {
        assert_eq!(lane_width_for_key("simd-cpu-f32:4"), crate::runtime::simd::LANES32);
        assert_eq!(lane_width_for_key("simd-cpu-f32"), crate::runtime::simd::LANES32);
        assert_eq!(lane_width_for_key("simd-cpu:4"), crate::runtime::simd::LANES);
        assert_eq!(lane_width_for_key("simd-cpu"), crate::runtime::simd::LANES);
        assert_eq!(lane_width_for_key("cpu"), 1);
        assert_eq!(lane_width_for_key("batch-cpu:8"), 1);
        assert_eq!(lane_width_for_key("engine"), 1);
    }

    #[test]
    fn parse_rejects_cross_kernel_lane_widths() {
        // An f32 fit relabeled under the f64 key (or any other lane-width
        // mismatch) must fail the load loudly, naming the widths.
        let record = |backend: &str, lanes: usize| {
            format!(
                "[\n{{\n  \"tune_schema\": 2\n}},\n{{\n  \"backend\": \"{backend}\",\n  \
                 \"variant\": \"rgb\",\n  \"lane_width\": {lanes},\n  \"class_m\": 16,\n  \
                 \"setup_ns\": 10.0,\n  \"per_problem_ns\": 500.0,\n  \"points\": 2\n}}\n]"
            )
        };
        // Matching widths load fine.
        assert!(Profile::parse(&record("simd-cpu:4", 8)).is_ok());
        assert!(Profile::parse(&record("simd-cpu-f32:4", 16)).is_ok());
        assert!(Profile::parse(&record("cpu", 1)).is_ok());
        // A 16-lane fit can never answer for the 8-lane kernel.
        let err = Profile::parse(&record("simd-cpu:4", 16)).unwrap_err().to_string();
        assert!(err.contains("16-lane") && err.contains("8-lane"), "{err}");
        // Nor the reverse, nor a scalar fit for a vector kernel.
        assert!(Profile::parse(&record("simd-cpu-f32:4", 8)).is_err());
        assert!(Profile::parse(&record("cpu", 8)).is_err());
        // Missing lane_width on a schema-2 record is refused outright.
        let missing = "[\n{\n  \"tune_schema\": 2\n},\n{\n  \"backend\": \"cpu\",\n  \
                       \"variant\": \"rgb\",\n  \"class_m\": 16,\n  \"setup_ns\": 10.0,\n  \
                       \"per_problem_ns\": 500.0,\n  \"points\": 2\n}\n]";
        let err = Profile::parse(missing).unwrap_err().to_string();
        assert!(err.contains("lane_width"), "{err}");
    }

    #[test]
    fn save_merged_is_idempotent_and_preserves_foreign_backends() {
        let dir = std::env::temp_dir().join(format!("tune_merge_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("TUNE_profile.json");
        let mut a = Profile::default();
        a.upsert(BackendFit {
            backend: "cpu".into(),
            variant: Variant::Rgb,
            classes: vec![ClassFit {
                class_m: 16,
                setup_ns: 1.0,
                per_problem_ns: 640.0,
                points: 2,
            }],
        });
        a.save_merged(&path).unwrap();
        let mut b = Profile::default();
        b.upsert(BackendFit {
            backend: "batch-cpu:4".into(),
            variant: Variant::Rgb,
            classes: vec![ClassFit {
                class_m: 64,
                setup_ns: 2.0,
                per_problem_ns: 700.0,
                points: 2,
            }],
        });
        b.save_merged(&path).unwrap();
        let merged = Profile::load(&path).unwrap();
        assert!(merged.backend("cpu", Variant::Rgb).is_some(), "foreign backend survived");
        assert!(merged.backend("batch-cpu:4", Variant::Rgb).is_some());
        // Idempotent: saving the same profile again changes nothing.
        let before = std::fs::read_to_string(&path).unwrap();
        b.save_merged(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), before);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn profiler_fits_cpu_backends_and_orders_them_sanely() {
        let manifest = Manifest::cpu_fallback();
        let opts = ProfilerOpts { runs: 2, warmup: 0, max_batch: 256, ..Default::default() };
        let slow = profile_backend(
            &mut CpuShardExecutor,
            "cpu",
            &manifest,
            Variant::Rgb,
            &opts,
        )
        .unwrap();
        let mut quad = BatchCpuBackend::new(4);
        let fast =
            profile_backend(&mut quad, "batch-cpu:4", &manifest, Variant::Rgb, &opts).unwrap();
        assert_eq!(slow.classes.len(), 2, "cpu_fallback has classes 16 and 64");
        for (s, f) in slow.classes.iter().zip(&fast.classes) {
            assert_eq!(s.class_m, f.class_m);
            assert!(s.per_problem_ns > 0.0 && f.per_problem_ns > 0.0);
        }
        // The 4-thread backend must not measure meaningfully SLOWER per
        // problem than the single-thread stand-in on the large class (on
        // multicore hosts it is faster; on a single core the scoped-
        // thread overhead is bounded — this is a sanity bound, not a
        // parallel-speedup assertion, which would flake on 1-core CI).
        let s64 = slow.class(64).unwrap();
        let f64_ = fast.class(64).unwrap();
        assert!(
            f64_.per_problem_ns < s64.per_problem_ns * 1.5,
            "4-thread marginal rate way off: {} vs {}",
            f64_.per_problem_ns,
            s64.per_problem_ns
        );
        // Accuracy rows exist and predictions are within an order of
        // magnitude (this is a smoke bound, not a perf assertion).
        let rows =
            validate_fit(&mut CpuShardExecutor, &slow, &manifest, Variant::Rgb, &opts).unwrap();
        assert!(!rows.is_empty());
        for r in rows {
            assert!(r.measured_ns > 0);
            assert!(r.rel_err().abs() < 10.0, "wild prediction: {r:?}");
        }
    }
}

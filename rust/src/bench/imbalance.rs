//! Figures 1/2: workload distribution across a warp, naive vs cooperative.
//!
//! The paper's Figures 1 and 2 are schematic; we reproduce them as a
//! *measured* statistic. Running the serial Seidel solver per problem with
//! work-unit accounting (`SolveStats`) gives each thread's load under the
//! naive one-thread-one-LP mapping; the cooperative mapping spreads the
//! same total across the warp. The imbalance factor (max/mean per warp) is
//! the quantity Figure 1's ragged bars depict.

use crate::gen;
use crate::lp::types::Problem;
use crate::solvers::seidel;
use crate::util::{Rng, Table};

/// Work-unit loads of one warp of problems under both mappings.
#[derive(Clone, Debug)]
pub struct WarpLoad {
    /// Per-thread work units, naive mapping (one LP per thread).
    pub naive: Vec<usize>,
    /// Per-thread work units after cooperative redistribution (even split).
    pub cooperative: Vec<usize>,
}

impl WarpLoad {
    pub fn imbalance(loads: &[usize]) -> f64 {
        let max = *loads.iter().max().unwrap_or(&0) as f64;
        let mean = loads.iter().sum::<usize>() as f64 / loads.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// Measure a warp's load distribution. `problems.len()` is the warp width.
pub fn warp_load(problems: &[Problem]) -> WarpLoad {
    let naive: Vec<usize> = problems
        .iter()
        .map(|p| {
            let (_, st) = seidel::solve_ordered_with_stats(p);
            st.work_units + p.m() // violation scans + the per-constraint checks
        })
        .collect();
    let total: usize = naive.iter().sum();
    let w = problems.len().max(1);
    let mut cooperative = vec![total / w; w];
    for c in cooperative.iter_mut().take(total % w) {
        *c += 1;
    }
    WarpLoad { naive, cooperative }
}

/// Sweep warp imbalance over LP sizes: the Fig 1-vs-Fig 2 contrast as
/// numbers (mean over `warps` random warps of 32 threads each).
pub fn imbalance_table(seed: u64, sizes: &[usize], warps: usize) -> Table {
    let mut table = Table::new(&[
        "lp_size",
        "naive_imbalance",
        "coop_imbalance",
        "naive_max_wu",
        "mean_wu",
    ]);
    let mut rng = Rng::new(seed);
    for &m in sizes {
        let mut naive_imb = 0.0;
        let mut coop_imb = 0.0;
        let mut naive_max = 0usize;
        let mut mean_wu = 0.0;
        for _ in 0..warps {
            let problems = gen::independent_batch(&mut rng, 32, m);
            let wl = warp_load(&problems);
            naive_imb += WarpLoad::imbalance(&wl.naive);
            coop_imb += WarpLoad::imbalance(&wl.cooperative);
            naive_max = naive_max.max(*wl.naive.iter().max().unwrap());
            mean_wu += wl.naive.iter().sum::<usize>() as f64 / 32.0;
        }
        let w = warps as f64;
        table.push_row(vec![
            m.to_string(),
            format!("{:.3}", naive_imb / w),
            format!("{:.3}", coop_imb / w),
            naive_max.to_string(),
            format!("{:.1}", mean_wu / w),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cooperative_is_balanced() {
        let mut rng = Rng::new(1);
        let problems = gen::independent_batch(&mut rng, 32, 24);
        let wl = warp_load(&problems);
        assert!(WarpLoad::imbalance(&wl.cooperative) < 1.05);
        assert_eq!(
            wl.naive.iter().sum::<usize>(),
            wl.cooperative.iter().sum::<usize>()
        );
    }

    #[test]
    fn naive_is_imbalanced_for_random_lps() {
        let mut rng = Rng::new(2);
        let problems = gen::independent_batch(&mut rng, 32, 64);
        let wl = warp_load(&problems);
        // Random LPs have wildly varying violation patterns; imbalance > 1.
        assert!(WarpLoad::imbalance(&wl.naive) > 1.1, "{:?}", wl.naive);
    }

    #[test]
    fn table_has_one_row_per_size() {
        let t = imbalance_table(3, &[8, 16], 2);
        assert_eq!(t.rows.len(), 2);
    }
}

//! Figure 6: reduction-strategy performance versus contention.
//!
//! The paper compares CUDA shared-memory atomics, global atomics, and CUB
//! device-wide segmented reduction while varying *contention* — how many
//! elements fold into one output cell (2 .. 512, the kernel block size).
//! The RGB kernel's u_left/u_right accumulation is exactly such a folding.
//!
//! Host-ISA analog (DESIGN.md §2): the same three mechanisms expressed with
//! CPU threads —
//!   * `GlobalAtomic`:  all threads `fetch_min` into one shared output
//!     array (cache-line ping-pong grows with contention, like global
//!     atomics in DRAM/L2);
//!   * `ShardedAtomic`: each thread folds into a private shard, then a
//!     merge pass (the shared-memory-atomics analog: contention never
//!     leaves the local fast path);
//!   * `SegmentedReduce`: contiguous segments split across threads, each
//!     reduced serially (the CUB device-segmented-reduce analog).

use std::sync::atomic::{AtomicU32, Ordering};

use crate::util::Rng;

/// The three mechanisms of Figure 6.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    GlobalAtomic,
    ShardedAtomic,
    SegmentedReduce,
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::GlobalAtomic => "global-atomic",
            Method::ShardedAtomic => "sharded-atomic",
            Method::SegmentedReduce => "segmented-reduce",
        }
    }

    pub fn all() -> [Method; 3] {
        [Method::GlobalAtomic, Method::ShardedAtomic, Method::SegmentedReduce]
    }
}

/// Workload: `n` u32 values; `contention` consecutive values fold into one
/// output cell via `min` (n must be divisible by contention).
pub struct Workload {
    pub data: Vec<u32>,
    pub contention: usize,
}

impl Workload {
    pub fn new(rng: &mut Rng, n: usize, contention: usize) -> Workload {
        assert!(contention > 0 && n % contention == 0);
        let data = (0..n).map(|_| rng.next_u64() as u32 | 1).collect();
        Workload { data, contention }
    }

    pub fn cells(&self) -> usize {
        self.data.len() / self.contention
    }
}

/// Reference serial result (tests).
pub fn reduce_serial(w: &Workload) -> Vec<u32> {
    w.data
        .chunks(w.contention)
        .map(|c| c.iter().copied().min().unwrap())
        .collect()
}

/// All threads fetch_min into one shared output array.
pub fn reduce_global_atomic(w: &Workload, threads: usize) -> Vec<u32> {
    let cells: Vec<AtomicU32> = (0..w.cells()).map(|_| AtomicU32::new(u32::MAX)).collect();
    let chunk = w.data.len().div_ceil(threads.max(1));
    std::thread::scope(|s| {
        for (t, slice) in w.data.chunks(chunk).enumerate() {
            let cells = &cells;
            let base = t * chunk;
            s.spawn(move || {
                for (k, &v) in slice.iter().enumerate() {
                    let cell = (base + k) / w.contention;
                    cells[cell].fetch_min(v, Ordering::Relaxed);
                }
            });
        }
    });
    cells.into_iter().map(|c| c.into_inner()).collect()
}

/// Per-thread private shards, merged at the end (shared-memory analog).
pub fn reduce_sharded_atomic(w: &Workload, threads: usize) -> Vec<u32> {
    let ncells = w.cells();
    let chunk = w.data.len().div_ceil(threads.max(1));
    let shards: Vec<Vec<u32>> = std::thread::scope(|s| {
        let handles: Vec<_> = w
            .data
            .chunks(chunk)
            .enumerate()
            .map(|(t, slice)| {
                let base = t * chunk;
                s.spawn(move || {
                    // Shard covers only the cell range this thread touches.
                    let lo = base / w.contention;
                    let hi = (base + slice.len() - 1) / w.contention;
                    let mut local = vec![u32::MAX; hi - lo + 1];
                    for (k, &v) in slice.iter().enumerate() {
                        let cell = (base + k) / w.contention - lo;
                        if v < local[cell] {
                            local[cell] = v;
                        }
                    }
                    (lo, local)
                })
            })
            .collect();
        let mut out = vec![Vec::new(); handles.len()];
        let mut offs = vec![0usize; handles.len()];
        for (i, h) in handles.into_iter().enumerate() {
            let (lo, local) = h.join().unwrap();
            offs[i] = lo;
            out[i] = local;
        }
        // Merge pass.
        let mut merged = vec![u32::MAX; ncells];
        for (lo, local) in offs.into_iter().zip(out) {
            for (k, v) in local.into_iter().enumerate() {
                if v < merged[lo + k] {
                    merged[lo + k] = v;
                }
            }
        }
        vec![merged]
    });
    shards.into_iter().next().unwrap()
}

/// Contiguous segments split across threads, reduced serially.
pub fn reduce_segmented(w: &Workload, threads: usize) -> Vec<u32> {
    let ncells = w.cells();
    let mut out = vec![u32::MAX; ncells];
    let cell_chunk = ncells.div_ceil(threads.max(1)).max(1);
    std::thread::scope(|s| {
        for (t, out_slice) in out.chunks_mut(cell_chunk).enumerate() {
            let data = &w.data;
            let first_cell = t * cell_chunk;
            s.spawn(move || {
                for (k, o) in out_slice.iter_mut().enumerate() {
                    let cell = first_cell + k;
                    let seg = &data[cell * w.contention..(cell + 1) * w.contention];
                    *o = seg.iter().copied().min().unwrap();
                }
            });
        }
    });
    out
}

/// Run one method.
pub fn run(method: Method, w: &Workload, threads: usize) -> Vec<u32> {
    match method {
        Method::GlobalAtomic => reduce_global_atomic(w, threads),
        Method::ShardedAtomic => reduce_sharded_atomic(w, threads),
        Method::SegmentedReduce => reduce_segmented(w, threads),
    }
}

/// Contention levels of the paper's Figure 6 (2 .. 512).
pub const CONTENTIONS: &[usize] = &[2, 4, 8, 16, 32, 64, 128, 256, 512];

#[cfg(test)]
mod tests {
    use super::*;

    fn workload(contention: usize) -> Workload {
        let mut rng = Rng::new(42);
        Workload::new(&mut rng, 1 << 14, contention)
    }

    #[test]
    fn all_methods_agree_with_serial() {
        for contention in [2, 16, 512] {
            let w = workload(contention);
            let want = reduce_serial(&w);
            for m in Method::all() {
                assert_eq!(run(m, &w, 4), want, "{m:?} c={contention}");
            }
        }
    }

    #[test]
    fn single_thread_works() {
        let w = workload(8);
        let want = reduce_serial(&w);
        for m in Method::all() {
            assert_eq!(run(m, &w, 1), want, "{m:?}");
        }
    }

    #[test]
    fn cell_count() {
        let w = workload(16);
        assert_eq!(w.cells(), (1 << 14) / 16);
    }
}

//! Ablation studies of the design choices DESIGN.md calls out.
//!
//! * **Randomization** — Seidel's namesake shuffle. An adversarially sorted
//!   constraint order forces a re-solve at (nearly) every step (the paper's
//!   §2.1 "worst case input set"); random order restores expected O(m).
//! * **Padding waste** — the cost of routing problems of size m into a
//!   compiled bucket of size M > m (the price of AOT shape bucketing).
//! * **Replicated vs independent batches** — the paper benchmarks one LP
//!   copied B times; independent problems change the tile early-exit odds.
//! * **Batch window** — serving latency/throughput against the batcher's
//!   deadline (the dynamic-batching knob).

use std::time::Duration;

use crate::bench::harness::{bench, BenchOpts};
use crate::coordinator::{Config, Service};
use crate::gen;
use crate::lp::types::{HalfPlane, Problem};
use crate::runtime::{Engine, Variant};
use crate::solvers::seidel;
use crate::util::{Rng, Table, Timer};

/// An adversarial 2-D problem: m constraints at slowly rotating angles with
/// shrinking offsets, sorted so each one cuts the previous optimum —
/// processed in the given order, Seidel re-solves at nearly every step.
pub fn adversarial_problem(m: usize) -> Problem {
    let mut cons = Vec::with_capacity(m);
    for k in 0..m {
        // Nearly-horizontal ceilings descending toward y <= 2: each one cuts
        // the previous optimum (which sits on the previous, higher ceiling).
        // A small alternating tilt keeps intersections well-defined.
        let tilt = 1e-3 * (1.0 + (k % 7) as f64) * if k % 2 == 0 { 1.0 } else { -1.0 };
        let b = 10.0 - 8.0 * (k as f64 + 1.0) / m.max(1) as f64;
        cons.push(HalfPlane::new(tilt, 1.0, b).normalized());
    }
    Problem::new(cons, [0.0, 1.0])
}

/// Ablation 1: sorted (adversarial) vs shuffled constraint order, CPU
/// Seidel, sweeping m. Columns are total work units (the O(m) vs O(m^2)
/// contrast) and wall time.
pub fn randomization_table(sizes: &[usize], opts: BenchOpts) -> Table {
    let mut table = Table::new(&[
        "m",
        "sorted_wu",
        "shuffled_wu",
        "sorted_ms",
        "shuffled_ms",
        "wu_ratio",
    ]);
    for &m in sizes {
        let p = adversarial_problem(m);
        let (_, st_sorted) = seidel::solve_ordered_with_stats(&p);

        // Average shuffled work units over a few permutations.
        let mut rng = Rng::new(0xAB1);
        let mut wu_sh = 0usize;
        const REPS: usize = 8;
        for _ in 0..REPS {
            let perm = rng.permutation(m);
            let shuffled = Problem {
                constraints: perm.iter().map(|&i| p.constraints[i as usize]).collect(),
                obj: p.obj,
            };
            let (_, st) = seidel::solve_ordered_with_stats(&shuffled);
            wu_sh += st.work_units;
        }
        wu_sh /= REPS;

        let sorted_ms = bench(&format!("sorted/m{m}"), opts, || {
            std::hint::black_box(seidel::solve_ordered(&p));
        })
        .mean_ms();
        let mut rng2 = Rng::new(0xAB2);
        let shuffled_ms = bench(&format!("shuffled/m{m}"), opts, || {
            std::hint::black_box(seidel::solve(&p, &mut rng2));
        })
        .mean_ms();

        table.push_row(vec![
            m.to_string(),
            st_sorted.work_units.to_string(),
            wu_sh.to_string(),
            format!("{sorted_ms:.4}"),
            format!("{shuffled_ms:.4}"),
            format!("{:.1}", st_sorted.work_units as f64 / wu_sh.max(1) as f64),
        ]);
    }
    table
}

/// Ablation 2: padding waste — time to solve B problems of true size m
/// through buckets of increasing M (same problems, same batch).
pub fn padding_table(
    engine: &Engine,
    batch: usize,
    true_m: usize,
    bucket_sizes: &[usize],
    opts: BenchOpts,
) -> anyhow::Result<Table> {
    let mut table = Table::new(&["bucket_m", "waste_frac", "time_ms", "overhead_vs_exact"]);
    let mut rng = Rng::new(0xAB3);
    let problems = gen::independent_batch(&mut rng, batch, true_m);
    let mut exact_ms = None;
    for &bm in bucket_sizes {
        if bm < true_m || engine.manifest().find(Variant::Rgb, batch, bm).is_none() {
            continue;
        }
        let bucket = engine.manifest().find(Variant::Rgb, batch, bm).unwrap().clone();
        let mut rng2 = Rng::new(0xAB4);
        let pb = crate::runtime::pack(&problems, bucket.batch, bucket.m, Some(&mut rng2))?;
        engine.execute_packed(&bucket, &pb)?; // warm
        let r = bench(&format!("pad/m{bm}"), opts, || {
            engine.execute_packed(&bucket, &pb).expect("exec");
        });
        let ms = r.mean_ms();
        if exact_ms.is_none() {
            exact_ms = Some(ms);
        }
        table.push_row(vec![
            bm.to_string(),
            format!("{:.3}", 1.0 - true_m as f64 / bm as f64),
            format!("{ms:.3}"),
            format!("{:.2}x", ms / exact_ms.unwrap()),
        ]);
    }
    Ok(table)
}

/// Ablation 3: replicated (paper methodology) vs independent batches.
pub fn batch_mix_table(
    engine: &Engine,
    batch: usize,
    sizes: &[usize],
    opts: BenchOpts,
) -> anyhow::Result<Table> {
    let mut table = Table::new(&["m", "replicated_ms", "independent_ms", "ratio"]);
    for &m in sizes {
        if engine.manifest().fit(Variant::Rgb, batch, m).is_none() {
            continue;
        }
        let time_for = |problems: &[Problem]| -> f64 {
            let mut rng = Rng::new(1);
            engine.solve(Variant::Rgb, problems, Some(&mut rng)).expect("warm");
            bench(&format!("mix/m{m}"), opts, || {
                engine
                    .solve(Variant::Rgb, problems, Some(&mut rng))
                    .expect("solve");
            })
            .mean_ms()
        };
        let mut rng = Rng::new(0xAB5 ^ m as u64);
        let rep = time_for(&gen::replicated_batch(&mut rng, batch, m));
        let ind = time_for(&gen::independent_batch(&mut rng, batch, m));
        table.push_row(vec![
            m.to_string(),
            format!("{rep:.3}"),
            format!("{ind:.3}"),
            format!("{:.2}", ind / rep),
        ]);
    }
    Ok(table)
}

/// Ablation 4: serving batch-window sweep — throughput and mean batch
/// occupancy versus the batcher deadline under a fixed offered load.
pub fn batch_window_table(
    artifact_dir: &std::path::Path,
    waits_ms: &[u64],
    requests: usize,
    m: usize,
) -> anyhow::Result<Table> {
    let mut table = Table::new(&["max_wait_ms", "throughput_lps", "batches", "occupancy"]);
    for &w in waits_ms {
        let config = Config {
            max_wait: Duration::from_millis(w),
            // The window sweep only means anything when the window is the
            // sole early-close trigger, so pin the fixed policy here.
            policy: crate::coordinator::ClosePolicy::Fixed,
            ..Config::default()
        };
        let service = Service::start(artifact_dir, config)?;
        let mut rng = Rng::new(0xAB6);
        let problems = gen::independent_batch(&mut rng, requests, m);
        let t = Timer::start();
        service.solve_all(&problems)?;
        let secs = t.elapsed_ns() as f64 / 1e9;
        let snap = service.metrics().snapshot();
        table.push_row(vec![
            w.to_string(),
            format!("{:.0}", requests as f64 / secs),
            snap.batches.to_string(),
            format!("{:.3}", snap.mean_occupancy),
        ]);
        service.shutdown();
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::brute;
    use crate::lp::types::Status;

    #[test]
    fn adversarial_problem_is_feasible_and_forcing() {
        let p = adversarial_problem(32);
        assert_eq!(brute::solve(&p).status, Status::Optimal);
        let (_, st) = seidel::solve_ordered_with_stats(&p);
        // Sorted order must force many re-solves (that is its purpose).
        assert!(st.violations > 16, "violations {}", st.violations);
    }

    #[test]
    fn randomization_table_shape() {
        let opts = BenchOpts { warmup_iters: 0, measure_iters: 1, max_seconds: 5.0 };
        let t = randomization_table(&[32, 64], opts);
        assert_eq!(t.rows.len(), 2);
        // Work-unit ratio must show the sorted order doing more work.
        let ratio: f64 = t.rows[1][5].parse().unwrap();
        assert!(ratio > 1.5, "ratio {ratio}");
    }
}

//! Figure reproduction sweeps (DESIGN.md §5): one function per paper figure
//! producing a [`Table`] with the same axes/series the paper plots.
//!
//! Scaling: the paper's largest points (batch 16384, m 8192) ran on a Titan
//! V; our substrate is XLA-CPU under Pallas interpret mode, so sweeps stop
//! at the scaled maxima compiled into `artifacts/` (batch 4096, m 256). The
//! *shape* — who wins, how each series scales, where crossovers fall — is
//! the reproduction target (EXPERIMENTS.md records paper-vs-measured).
//!
//! Timing follows the paper's method (§4): a measurement starts after
//! problem initialization and ends when results are in host-usable memory;
//! for the engine paths that is pack + literal staging + execute + unpack.

use crate::bench::harness::{bench, BenchOpts};
use crate::gen;
use crate::lp::types::Problem;
use crate::runtime::{Engine, ShardedEngine, Variant};
use crate::solvers::batch_cpu::{self, Algo};
use crate::util::{Rng, Table};

/// Shared sweep context.
pub struct FigureCtx<'a> {
    pub engine: &'a Engine,
    pub opts: BenchOpts,
    pub seed: u64,
    pub cpu_threads: usize,
    /// Replicate one LP per (batch, m) point (the paper's methodology) or
    /// generate independent problems (ablation).
    pub replicated: bool,
}

impl<'a> FigureCtx<'a> {
    pub fn new(engine: &'a Engine) -> FigureCtx<'a> {
        FigureCtx {
            engine,
            opts: BenchOpts::from_env(),
            seed: 2019,
            cpu_threads: batch_cpu::default_threads(),
            replicated: true,
        }
    }

    fn problems(&self, batch: usize, m: usize) -> Vec<Problem> {
        let mut rng = Rng::new(self.seed ^ ((batch as u64) << 32) ^ m as u64);
        if self.replicated {
            gen::replicated_batch(&mut rng, batch, m)
        } else {
            gen::independent_batch(&mut rng, batch, m)
        }
    }
}

/// The series the paper plots, mapped to our substitutes (DESIGN.md §2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Series {
    /// The paper's contribution (optimized Pallas kernel via PJRT).
    Rgb,
    /// Gurung & Ray's batch GPU simplex (batched XLA simplex comparator).
    BatchSimplex,
    /// mGLPK: multicore CPU simplex, one problem per thread.
    McpuSimplex,
    /// CLP: single-core CPU simplex.
    CpuSimplex,
    /// Multicore CPU Seidel (best-case CPU incremental baseline).
    McpuSeidel,
}

impl Series {
    pub fn label(&self) -> &'static str {
        match self {
            Series::Rgb => "RGB",
            Series::BatchSimplex => "BatchSimplex(G&R)",
            Series::McpuSimplex => "mCPU-Simplex(mGLPK)",
            Series::CpuSimplex => "CPU-Simplex(CLP)",
            Series::McpuSeidel => "mCPU-Seidel",
        }
    }

    pub fn all() -> [Series; 5] {
        [
            Series::Rgb,
            Series::BatchSimplex,
            Series::McpuSimplex,
            Series::CpuSimplex,
            Series::McpuSeidel,
        ]
    }
}

/// Time one (series, batch, m) point; None if that point is out of the
/// series' domain (e.g. no compiled bucket — like G&R's 511-constraint cap).
pub fn time_point(ctx: &FigureCtx<'_>, series: Series, batch: usize, m: usize) -> Option<f64> {
    let problems = ctx.problems(batch, m);
    let name = format!("{}/b{batch}/m{m}", series.label());
    let mut rng = Rng::new(ctx.seed ^ 0xBEEF);
    match series {
        Series::Rgb | Series::BatchSimplex => {
            let variant = if series == Series::Rgb { Variant::Rgb } else { Variant::Simplex };
            ctx.engine.manifest().fit(variant, batch, m)?;
            let r = bench(&name, ctx.opts, || {
                ctx.engine
                    .solve(variant, &problems, Some(&mut rng))
                    .expect("engine solve");
            });
            Some(r.mean_ms())
        }
        Series::McpuSimplex | Series::CpuSimplex | Series::McpuSeidel => {
            // Keep O(batch * m^3) CPU points inside the bench budget.
            if series != Series::McpuSeidel && (batch as u64) * (m as u64).pow(2) > 1 << 26 {
                return None;
            }
            let (algo, threads) = match series {
                Series::McpuSimplex => (Algo::Simplex, ctx.cpu_threads),
                Series::CpuSimplex => (Algo::Simplex, 1),
                Series::McpuSeidel => (Algo::Seidel, ctx.cpu_threads),
                _ => unreachable!(),
            };
            let r = bench(&name, ctx.opts, || {
                batch_cpu::solve_batch(&problems, algo, threads, ctx.seed);
            });
            Some(r.mean_ms())
        }
    }
}

fn sweep_table(
    ctx: &FigureCtx<'_>,
    x_name: &str,
    points: &[(usize, usize)], // (batch, m)
    x_of: impl Fn(usize, usize) -> usize,
) -> Table {
    let mut header = vec![x_name.to_string()];
    header.extend(Series::all().iter().map(|s| s.label().to_string()));
    let mut table = Table { header, rows: Vec::new() };
    for &(batch, m) in points {
        let mut row = vec![x_of(batch, m).to_string()];
        for s in Series::all() {
            row.push(match time_point(ctx, s, batch, m) {
                Some(ms) => format!("{ms:.3}"),
                None => "-".to_string(),
            });
        }
        table.rows.push(row);
        eprintln!("  {}", table.rows.last().unwrap().join("\t"));
    }
    table
}

/// Figures 3a-3c: time vs LP size for a fixed batch count.
pub fn fig3(ctx: &FigureCtx<'_>, batch: usize, sizes: &[usize]) -> Table {
    let points: Vec<(usize, usize)> = sizes.iter().map(|&m| (batch, m)).collect();
    sweep_table(ctx, "lp_size", &points, |_, m| m)
}

/// Figures 4a-4b: time vs batch count for a fixed LP size.
pub fn fig4(ctx: &FigureCtx<'_>, m: usize, batches: &[usize]) -> Table {
    let points: Vec<(usize, usize)> = batches.iter().map(|&b| (b, m)).collect();
    sweep_table(ctx, "batch", &points, |b, _| b)
}

/// Figure 5: fraction of RGB wall time spent on memory management over a
/// (batch x size) grid — the paper's surface plot, as a table.
pub fn fig5(ctx: &FigureCtx<'_>, batches: &[usize], sizes: &[usize]) -> anyhow::Result<Table> {
    let mut table = Table::new(&["batch", "lp_size", "mem_frac", "total_ms"]);
    for &batch in batches {
        for &m in sizes {
            if ctx.engine.manifest().fit(Variant::Rgb, batch, m).is_none() {
                continue;
            }
            let problems = ctx.problems(batch, m);
            let mut rng = Rng::new(ctx.seed);
            // Warm the executable cache, then measure the timing split.
            ctx.engine.solve(Variant::Rgb, &problems, Some(&mut rng))?;
            let mut acc = crate::runtime::ExecTiming::default();
            for _ in 0..ctx.opts.measure_iters.max(1) {
                let (_, t) = ctx.engine.solve(Variant::Rgb, &problems, Some(&mut rng))?;
                acc.accumulate(&t);
            }
            table.push_row(vec![
                batch.to_string(),
                m.to_string(),
                format!("{:.4}", acc.memory_fraction()),
                format!(
                    "{:.3}",
                    acc.total_ns() as f64 / 1e6 / ctx.opts.measure_iters.max(1) as f64
                ),
            ]);
            eprintln!("  {}", table.rows.last().unwrap().join("\t"));
        }
    }
    Ok(table)
}

/// Figure-5 companion: the pipelining win. A fixed (chunk, m) workload is
/// split into `n_chunks` chunks and run twice — serially (one
/// `Engine::solve` per chunk) and through the double-buffered
/// `Engine::solve_stream` — reporting wall time, overlap ratio, and the
/// memory fraction. The pipelined column's critical path dropping below
/// the serial column is the win Figure 5 motivates.
pub fn fig5_pipeline(
    ctx: &FigureCtx<'_>,
    chunk: usize,
    m: usize,
    chunk_counts: &[usize],
) -> anyhow::Result<Table> {
    let mut table = Table::new(&[
        "chunks",
        "serial_ms",
        "pipelined_ms",
        "speedup",
        "overlap",
        "mem_frac",
    ]);
    if ctx.engine.manifest().fit(Variant::Rgb, chunk, m).is_none() {
        return Ok(table);
    }
    for &n_chunks in chunk_counts {
        let problems = ctx.problems(chunk * n_chunks, m);
        let chunks: Vec<&[Problem]> = problems.chunks(chunk).collect();
        if chunks.is_empty() {
            continue;
        }

        // Warm the executable cache so neither path pays the one-time
        // XLA compile inside its timed region.
        let mut rng = Rng::new(ctx.seed);
        ctx.engine.solve(Variant::Rgb, chunks[0], Some(&mut rng))?;

        // Serial: one engine call per chunk.
        let mut rng = Rng::new(ctx.seed);
        let mut serial = crate::runtime::ExecTiming::default();
        for c in &chunks {
            let (_, t) = ctx.engine.solve(Variant::Rgb, *c, Some(&mut rng))?;
            serial.accumulate(&t);
        }

        // Pipelined: same chunks, same seed, one stream.
        let mut rng = Rng::new(ctx.seed);
        let (_, stream) =
            ctx.engine
                .solve_stream(Variant::Rgb, chunks.iter().copied(), Some(&mut rng))?;

        let serial_ms = serial.critical_path_ns as f64 / 1e6;
        let stream_ms = stream.critical_path_ns as f64 / 1e6;
        table.push_row(vec![
            n_chunks.to_string(),
            format!("{serial_ms:.3}"),
            format!("{stream_ms:.3}"),
            format!("{:.3}", serial_ms / stream_ms.max(1e-9)),
            format!("{:.3}", stream.overlap_ratio()),
            format!("{:.4}", stream.memory_fraction()),
        ]);
        eprintln!("  {}", table.rows.last().unwrap().join("\t"));
    }
    Ok(table)
}

/// Shard-count sweep: the same workload through [`ShardedEngine`] at each
/// shard count — wall time, speedup over one shard, busy-time balance, and
/// the chunk size the batch-size-aware policy picked. One engine (PJRT
/// client + executable cache) is built per shard, mirroring the one-client-
/// per-device deployment; warmup happens outside the timed region.
pub fn fig_shard_sweep(
    artifact_dir: &std::path::Path,
    n: usize,
    m: usize,
    shard_counts: &[usize],
) -> anyhow::Result<Table> {
    let mut table = Table::new(&["shards", "chunk", "wall_ms", "speedup", "balance", "klps"]);
    // Honour the fast-mode convention the figure benches use (main.rs
    // exports the env var under --fast).
    let n = if std::env::var_os("BATCH_LP2D_BENCH_FAST").is_some() {
        n.min(512)
    } else {
        n
    };
    let mut prng = Rng::new(2019 ^ ((n as u64) << 32) ^ m as u64);
    let problems = gen::independent_batch(&mut prng, n, m);
    let mut base_ms: Option<f64> = None;
    for &shards in shard_counts {
        let mut sharded = ShardedEngine::new(artifact_dir, shards)?;
        sharded.warmup(Variant::Rgb)?;
        let chunk = sharded.plan_chunk(Variant::Rgb, n, m)?;
        let mut rng = Rng::new(2019);
        let (solutions, report) = sharded.solve_all(Variant::Rgb, &problems, Some(&mut rng))?;
        anyhow::ensure!(solutions.len() == n, "lost solutions in shard sweep");
        let wall_ms = report.timing.critical_path_ns.max(1) as f64 / 1e6;
        let base = *base_ms.get_or_insert(wall_ms);
        table.push_row(vec![
            shards.to_string(),
            chunk.to_string(),
            format!("{wall_ms:.3}"),
            format!("{:.3}", base / wall_ms),
            format!("{:.3}", report.balance()),
            format!("{:.1}", n as f64 / wall_ms),
        ]);
        eprintln!("  {}", table.rows.last().unwrap().join("\t"));
    }
    Ok(table)
}

/// Pipeline-depth sweep (companion to the shard sweep): the same workload
/// through a 2-shard [`ShardedEngine`] at each staged-queue depth — wall
/// time, speedup over depth 2, busy-time balance, and steal counts. Deeper
/// rings only help when execution times are bursty enough that double
/// buffering drains; the steal column shows how much rebalancing the
/// deeper backlog enabled.
pub fn fig_depth_sweep(
    artifact_dir: &std::path::Path,
    n: usize,
    m: usize,
    depths: &[usize],
) -> anyhow::Result<Table> {
    let mut table = Table::new(&["depth", "chunk", "wall_ms", "speedup", "balance", "steals"]);
    let n = if std::env::var_os("BATCH_LP2D_BENCH_FAST").is_some() {
        n.min(512)
    } else {
        n
    };
    let mut prng = Rng::new(2019 ^ ((n as u64) << 32) ^ m as u64);
    let problems = gen::independent_batch(&mut prng, n, m);
    let mut base_ms: Option<f64> = None;
    for &depth in depths {
        let mut sharded = ShardedEngine::new(artifact_dir, 2)?
            .with_depth(crate::runtime::PipelineDepth::new(depth));
        sharded.warmup(Variant::Rgb)?;
        let chunk = sharded.plan_chunk(Variant::Rgb, n, m)?;
        let mut rng = Rng::new(2019);
        let (solutions, report) = sharded.solve_all(Variant::Rgb, &problems, Some(&mut rng))?;
        anyhow::ensure!(solutions.len() == n, "lost solutions in depth sweep");
        let wall_ms = report.timing.critical_path_ns.max(1) as f64 / 1e6;
        let base = *base_ms.get_or_insert(wall_ms);
        table.push_row(vec![
            depth.to_string(),
            chunk.to_string(),
            format!("{wall_ms:.3}"),
            format!("{:.3}", base / wall_ms),
            format!("{:.3}", report.balance()),
            report.steals().to_string(),
        ]);
        eprintln!("  {}", table.rows.last().unwrap().join("\t"));
    }
    Ok(table)
}

/// Figures 7a-7b: speedup of optimized RGB over NaiveRGB, kernel time only
/// (the paper excludes transfer), versus LP size at a fixed batch.
///
/// Deviation from the paper's replicate-one-LP batches: points use
/// *independent* problems so the measured ratio reflects the average
/// violation pattern rather than one random LP's (a single replicated LP
/// makes each point's early-exit behaviour all-or-nothing, which swamps
/// the trend in variance).
pub fn fig7(ctx: &FigureCtx<'_>, batch: usize, sizes: &[usize]) -> anyhow::Result<Table> {
    let mut table = Table::new(&["lp_size", "naive_ms", "rgb_ms", "speedup"]);
    for &m in sizes {
        if ctx.engine.manifest().fit(Variant::Rgb, batch, m).is_none()
            || ctx.engine.manifest().fit(Variant::Naive, batch, m).is_none()
        {
            continue;
        }
        let mut prng = Rng::new(ctx.seed ^ ((batch as u64) << 32) ^ m as u64);
        let problems = gen::independent_batch(&mut prng, batch, m);
        let kernel_ms = |variant: Variant| -> anyhow::Result<f64> {
            let mut rng = Rng::new(ctx.seed);
            ctx.engine.solve(variant, &problems, Some(&mut rng))?; // warm
            let mut total = 0u64;
            let iters = ctx.opts.measure_iters.max(1);
            for _ in 0..iters {
                let (_, t) = ctx.engine.solve(variant, &problems, Some(&mut rng))?;
                total += t.execute_ns; // kernel-only, as in the paper
            }
            Ok(total as f64 / 1e6 / iters as f64)
        };
        let naive = kernel_ms(Variant::Naive)?;
        let rgb = kernel_ms(Variant::Rgb)?;
        table.push_row(vec![
            m.to_string(),
            format!("{naive:.3}"),
            format!("{rgb:.3}"),
            format!("{:.3}", naive / rgb),
        ]);
        eprintln!("  {}", table.rows.last().unwrap().join("\t"));
    }
    Ok(table)
}

/// Companion table of the `loadgen` bench: latency percentiles under the
/// scenario-diverse open-loop load models (p50/p95/p99 end-to-end,
/// queue-wait vs execute split, shed counts), one row per scenario.
/// Engine-free — the portable CPU-only shard mix serves without
/// artifacts — so it runs on any host, like the loadgen CI leg.
pub fn fig_loadgen(artifact_dir: &std::path::Path, requests: usize) -> anyhow::Result<Table> {
    use crate::bench::loadgen::{run_scenario, table, LoadgenOpts};
    use crate::gen::scenarios::Scenario;
    let requests = if std::env::var_os("BATCH_LP2D_BENCH_FAST").is_some() {
        requests.min(1_200)
    } else {
        requests
    };
    let opts = LoadgenOpts { requests, ..LoadgenOpts::default() };
    let mut reports = Vec::new();
    for sc in Scenario::ALL {
        let name = sc.name();
        reports.push(run_scenario(artifact_dir, sc, &opts)?);
        eprintln!("  {name} done");
    }
    Ok(table(&reports))
}

/// Companion table of the vectorized SoA backends: raw single-backend
/// throughput of `simd-cpu` (8 f64 lanes) and `simd-cpu-f32` (16
/// wire-precision lanes) vs the scalar `cpu`/`batch-cpu` executors over
/// the portable CPU bucket inventory, at equal thread counts on full
/// buckets. Engine-free, like the loadgen companion, so it runs on any
/// host; the `simd_micro`/`simd_f32_micro` records in
/// `BENCH_pipeline.json` gate the same ratios in CI.
pub fn fig_simd(threads: usize, iters: usize) -> anyhow::Result<Table> {
    use crate::runtime::backend::{Backend, BatchCpuBackend, CpuShardExecutor};
    use crate::runtime::{pack, Manifest, SimdCpuBackend, SimdCpuF32Backend};
    use crate::util::Timer;

    let iters = if std::env::var_os("BATCH_LP2D_BENCH_FAST").is_some() {
        1
    } else {
        iters.max(1)
    };
    let manifest = Manifest::cpu_fallback();
    let mut table = Table::new(&[
        "batch",
        "m",
        "cpu_klps",
        "batch_cpu_klps",
        "simd_klps",
        "simd_f32_klps",
        "simd_vs_batch",
        "f32_vs_f64",
    ]);
    for bucket in manifest.of_variant(Variant::Rgb) {
        let mut prng = Rng::new(2019 ^ ((bucket.batch as u64) << 32) ^ bucket.m as u64);
        let problems = gen::independent_batch(&mut prng, bucket.batch, bucket.m);
        let mut srng = Rng::new(2019);
        let pb = pack::pack(&problems, bucket.batch, bucket.m, Some(&mut srng))?;
        let mut klps = |backend: &mut dyn Backend| -> anyhow::Result<f64> {
            backend.execute_raw(bucket, &pb)?; // warm
            let t = Timer::start();
            for _ in 0..iters {
                backend.execute_raw(bucket, &pb)?;
            }
            let ms = t.elapsed_ns().max(1) as f64 / 1e6;
            Ok((bucket.batch * iters) as f64 / ms)
        };
        let cpu = klps(&mut CpuShardExecutor)?;
        let batch_cpu = klps(&mut BatchCpuBackend::new(threads))?;
        let simd = klps(&mut SimdCpuBackend::new(threads))?;
        let simd_f32 = klps(&mut SimdCpuF32Backend::new(threads))?;
        table.push_row(vec![
            bucket.batch.to_string(),
            bucket.m.to_string(),
            format!("{cpu:.1}"),
            format!("{batch_cpu:.1}"),
            format!("{simd:.1}"),
            format!("{simd_f32:.1}"),
            format!("{:.3}", simd / batch_cpu.max(1e-9)),
            format!("{:.3}", simd_f32 / simd.max(1e-9)),
        ]);
        eprintln!("  {}", table.rows.last().unwrap().join("\t"));
    }
    Ok(table)
}

/// Default sweep axes (must stay within the compiled artifact set).
pub const SIZES: &[usize] = &[16, 32, 64, 128, 256];
pub const BATCHES: &[usize] = &[128, 256, 512, 1024, 2048, 4096];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_labels_are_distinct() {
        let labels: std::collections::HashSet<_> =
            Series::all().iter().map(|s| s.label()).collect();
        assert_eq!(labels.len(), 5);
    }
}

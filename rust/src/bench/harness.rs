//! Micro/macro benchmark harness (the vendor set has no criterion).
//!
//! Warmup + repeated timed runs with summary statistics; benches built on
//! this print one TSV/markdown row per measurement so the figure harness
//! and `cargo bench` share machinery.

use crate::util::{Summary, Timer};

/// Harness options.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// Hard wall-clock budget for the measurement loop; once exceeded, stop
    /// early (keeps O(m^3) baselines from stalling a sweep).
    pub max_seconds: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup_iters: 2, measure_iters: 7, max_seconds: 20.0 }
    }
}

impl BenchOpts {
    /// Environment override: `BATCH_LP2D_BENCH_FAST=1` shrinks every loop
    /// (CI smoke mode).
    pub fn from_env() -> BenchOpts {
        let fast = std::env::var("BATCH_LP2D_BENCH_FAST").is_ok_and(|v| v != "0");
        if fast {
            BenchOpts { warmup_iters: 1, measure_iters: 3, max_seconds: 5.0 }
        } else {
            BenchOpts::default()
        }
    }
}

/// One benchmark result (times in milliseconds).
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub ms: Summary,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.ms.mean
    }
}

/// Time `f` under `opts`; `f` must perform one full unit of work per call.
pub fn bench<F: FnMut()>(name: &str, opts: BenchOpts, mut f: F) -> BenchResult {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let budget = Timer::start();
    let mut samples = Vec::with_capacity(opts.measure_iters);
    for _ in 0..opts.measure_iters {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_ms());
        if budget.elapsed_ms() > opts.max_seconds * 1e3 {
            break;
        }
    }
    BenchResult { name: name.to_string(), iters: samples.len(), ms: Summary::of(&samples) }
}

/// Pretty one-line report (mean ± std over iters).
pub fn report_line(r: &BenchResult) -> String {
    format!(
        "{:<44} {:>10.3} ms ±{:>8.3} (n={})",
        r.name, r.ms.mean, r.ms.std, r.iters
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let opts = BenchOpts { warmup_iters: 1, measure_iters: 5, max_seconds: 30.0 };
        let mut calls = 0usize;
        let r = bench("noop", opts, || calls += 1);
        assert_eq!(r.iters, 5);
        assert_eq!(calls, 6); // warmup + measured
        assert!(r.ms.mean >= 0.0);
    }

    #[test]
    fn budget_stops_early() {
        let opts = BenchOpts { warmup_iters: 0, measure_iters: 1000, max_seconds: 0.05 };
        let r = bench("sleepy", opts, || std::thread::sleep(std::time::Duration::from_millis(20)));
        assert!(r.iters < 1000, "iters {}", r.iters);
    }

    #[test]
    fn report_contains_name() {
        let opts = BenchOpts { warmup_iters: 0, measure_iters: 2, max_seconds: 1.0 };
        let r = bench("my-case", opts, || {});
        assert!(report_line(&r).contains("my-case"));
    }
}

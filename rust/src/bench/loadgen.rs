//! Open-loop load generator over the serving layer: drive the coordinator
//! with a [`Scenario`] traffic model and report latency percentiles —
//! the first benchmark measuring **latency under load** rather than
//! closed-loop throughput.
//!
//! Each run starts a [`Service`], replays the scenario's arrival
//! timestamps (open loop: the driver never waits for replies, so queueing
//! is real), and measures per-request end-to-end latency client-side
//! while the service's own metrics supply the queue-wait vs execute-time
//! split, shed counts, and padding gauges. Reports render as a markdown
//! table ([`table`]) and as flat JSON records merged into
//! `BENCH_pipeline.json` ([`merge_into_bench_json`]) so the perf gate and
//! the figure harness share one artifact.

use std::path::Path;
use std::time::{Duration, Instant};

use crate::coordinator::{BackendSpec, ClosePolicy, Config, Service, Snapshot, Ticket};
use crate::gen::scenarios::Scenario;
use crate::runtime::manifest::Variant;
use crate::runtime::PipelineDepth;
use crate::tune::{Observation, Profile};
use crate::util::stats::percentile_sorted;
use crate::util::{Rng, Table};

/// Load-generator knobs (service config + drive parameters).
#[derive(Clone, Debug)]
pub struct LoadgenOpts {
    pub requests: usize,
    /// Base arrival rate, requests/second (scenarios shape around it).
    pub rate: f64,
    /// Shard backend mix; empty = a portable CPU-only default.
    pub backends: Vec<BackendSpec>,
    pub depth: usize,
    pub policy: ClosePolicy,
    pub max_queue: usize,
    /// Interactive SLO (the `--slo-ms` knob) and the bulk bound.
    pub slo: Duration,
    pub bulk_slo: Duration,
    pub seed: u64,
    /// Time compression for `trace:PATH` replay (the `--replay-speed`
    /// knob): recorded arrival stamps are divided by this factor.
    /// Synthetic scenarios ignore it. 1.0 = real-time replay.
    pub replay_speed: f64,
}

impl Default for LoadgenOpts {
    fn default() -> Self {
        LoadgenOpts {
            requests: 6_000,
            rate: 4_000.0,
            backends: Vec::new(),
            depth: 2,
            policy: ClosePolicy::Adaptive,
            max_queue: 4_096,
            slo: Duration::from_millis(5),
            bulk_slo: Duration::from_millis(40),
            seed: 0x10AD,
            replay_speed: 1.0,
        }
    }
}

impl LoadgenOpts {
    /// The CPU-only shard mix used when none is given: two multicore
    /// batch-CPU shards plus the single-thread stand-in — runs on any
    /// host, no artifacts, heterogeneous weights.
    pub fn default_backends() -> Vec<BackendSpec> {
        vec![
            BackendSpec::BatchCpu { threads: 2 },
            BackendSpec::BatchCpu { threads: 2 },
            BackendSpec::Cpu,
        ]
    }
}

/// One scenario's measured serving behaviour.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    pub scenario: &'static str,
    pub policy: &'static str,
    pub requests: usize,
    /// Requests that completed with a solution (everything not shed).
    pub completed: usize,
    /// Requests shed by the bounded admission queue (ticket errors),
    /// split interactive/bulk from the service metrics.
    pub shed_interactive: u64,
    pub shed_bulk: u64,
    pub wall_s: f64,
    pub throughput_lps: f64,
    /// End-to-end latency percentiles (submit → solution), milliseconds.
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Admission queue-wait percentiles (the wait side of the split).
    pub queue_p50_ms: f64,
    pub queue_p95_ms: f64,
    pub queue_p99_ms: f64,
    /// Batch execute-side p99 (the execute side of the split).
    pub exec_p99_ms: f64,
    pub mean_occupancy: f64,
    pub padding_waste: f64,
    /// Batches closed by the work-conserving rules (idle + cost).
    pub adaptive_closes: u64,
    /// Per-class cost observations distilled from the run's metrics
    /// ([`class_observations`]) — the loadgen → tune-profile feed.
    pub observations: Vec<Observation>,
    /// The run's full final metrics snapshot, kept so callers can export
    /// it (the bench harness writes a Prometheus text exposition from it
    /// via `--metrics-out`).
    pub snapshot: Snapshot,
}

impl ScenarioReport {
    pub fn shed(&self) -> u64 {
        self.shed_interactive + self.shed_bulk
    }
}

/// Drive one scenario through a freshly started service and measure it.
pub fn run_scenario(
    artifact_dir: &Path,
    scenario: Scenario,
    opts: &LoadgenOpts,
) -> anyhow::Result<ScenarioReport> {
    let backends = if opts.backends.is_empty() {
        LoadgenOpts::default_backends()
    } else {
        opts.backends.clone()
    };
    let config = Config {
        max_wait: opts.slo,
        bulk_wait: opts.bulk_slo,
        policy: opts.policy,
        max_queue: opts.max_queue,
        backends,
        depth: PipelineDepth::new(opts.depth),
        ..Config::default()
    };
    let service = Service::start(artifact_dir, config)?;

    let mut rng = Rng::new(opts.seed);
    let reqs = scenario.generate_at_speed(&mut rng, opts.requests, opts.rate, opts.replay_speed)?;

    // Collector thread waits tickets concurrently with the driver so the
    // measured latency is (completion - submission), not (drive end - t).
    let (tk_tx, tk_rx) = std::sync::mpsc::channel::<(Ticket, Instant)>();
    let collector = std::thread::spawn(move || {
        let mut latencies_ms: Vec<f64> = Vec::new();
        let mut errors = 0usize;
        while let Ok((t, at)) = tk_rx.recv() {
            match t.wait() {
                Ok(_) => latencies_ms.push(at.elapsed().as_secs_f64() * 1e3),
                // Shed replies surface as ticket errors; they are counted
                // from the service metrics, not the latency sample.
                Err(_) => errors += 1,
            }
        }
        (latencies_ms, errors)
    });

    let t0 = Instant::now();
    for r in reqs {
        while (t0.elapsed().as_nanos() as u64) < r.at_ns {
            std::hint::spin_loop();
        }
        let at = Instant::now();
        let ticket = service
            .submit_with_class(r.problem, r.class)
            .map_err(|e| anyhow::anyhow!("submit: {e}"))?;
        tk_tx.send((ticket, at)).expect("collector alive");
    }
    drop(tk_tx);
    let (mut latencies_ms, _errors) = collector.join().expect("collector");
    let wall_s = t0.elapsed().as_secs_f64();
    let snap = service.metrics().snapshot();
    service.shutdown();

    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| {
        if latencies_ms.is_empty() {
            0.0
        } else {
            percentile_sorted(&latencies_ms, p)
        }
    };
    Ok(ScenarioReport {
        scenario: scenario.name(),
        policy: opts.policy.as_str(),
        requests: opts.requests,
        completed: latencies_ms.len(),
        shed_interactive: snap.shed_interactive,
        shed_bulk: snap.shed_bulk,
        wall_s,
        throughput_lps: latencies_ms.len() as f64 / wall_s.max(1e-9),
        p50_ms: pct(50.0),
        p95_ms: pct(95.0),
        p99_ms: pct(99.0),
        queue_p50_ms: snap.queue_wait_p50_ns as f64 / 1e6,
        queue_p95_ms: snap.queue_wait_p95_ns as f64 / 1e6,
        queue_p99_ms: snap.queue_wait_p99_ns as f64 / 1e6,
        exec_p99_ms: snap.exec_p99_ns as f64 / 1e6,
        mean_occupancy: snap.mean_occupancy,
        padding_waste: snap.padding_waste(),
        adaptive_closes: snap.closes.adaptive(),
        observations: class_observations(&snap),
        snapshot: snap,
    })
}

/// Distill a service metrics snapshot into per-class cost
/// [`Observation`]s: each class's occupied slots and batch count come
/// from the padding gauges, and the run's total execute-side time is
/// apportioned to classes by their share of true constraint rows (the
/// quantity the Seidel work actually scales with). Classes that saw no
/// traffic yield nothing.
pub fn class_observations(snap: &Snapshot) -> Vec<Observation> {
    let rows_sum: u64 = snap.padding.iter().map(|p| p.rows_used).sum();
    if rows_sum == 0 || snap.timing.execute_ns == 0 {
        return Vec::new();
    }
    let execute_ns = snap.timing.execute_ns as f64;
    snap.padding
        .iter()
        .filter(|p| p.batches > 0 && p.rows_used > 0)
        .map(|p| Observation {
            class_m: p.class_m,
            problems: (p.rows_total / p.class_m.max(1) as u64) as usize,
            busy_ns: execute_ns * p.rows_used as f64 / rows_sum as f64,
            samples: p.batches as usize,
        })
        .collect()
}

/// Fold the reports' observations into `TUNE_profile.json`-shaped state
/// on disk as a second fitting source next to the offline grid. The
/// attribution is only unambiguous when every shard runs the same
/// backend kind, so heterogeneous mixes are skipped (returning `None`);
/// a homogeneous mix absorbs into that kind's fit (created from the
/// observations alone if the backend was never grid-profiled) and
/// returns the number of observations fed.
pub fn absorb_into_profile(
    path: &Path,
    backends: &[BackendSpec],
    reports: &[ScenarioReport],
) -> anyhow::Result<Option<usize>> {
    let keys = BackendSpec::distinct_keys(backends);
    let [key] = keys.as_slice() else {
        return Ok(None);
    };
    let observations: Vec<Observation> =
        reports.iter().flat_map(|r| r.observations.iter().copied()).collect();
    if observations.is_empty() {
        return Ok(None);
    }
    let mut profile = if path.exists() { Profile::load(path)? } else { Profile::default() };
    profile.absorb(key, Variant::Rgb, &observations);
    profile.save_merged(path)?;
    Ok(Some(observations.len()))
}

/// The latency table: one row per scenario, the percentile columns the
/// acceptance gate greps for (`p99`, `shed`).
pub fn table(reports: &[ScenarioReport]) -> Table {
    let mut t = Table::new(&[
        "scenario",
        "policy",
        "requests",
        "completed",
        "shed",
        "LPs/s",
        "p50_ms",
        "p95_ms",
        "p99_ms",
        "queue_p99_ms",
        "exec_p99_ms",
        "occupancy",
        "padding_waste",
        "adaptive_closes",
    ]);
    for r in reports {
        t.push_row(vec![
            r.scenario.to_string(),
            r.policy.to_string(),
            r.requests.to_string(),
            r.completed.to_string(),
            r.shed().to_string(),
            format!("{:.0}", r.throughput_lps),
            format!("{:.3}", r.p50_ms),
            format!("{:.3}", r.p95_ms),
            format!("{:.3}", r.p99_ms),
            format!("{:.3}", r.queue_p99_ms),
            format!("{:.3}", r.exec_p99_ms),
            format!("{:.3}", r.mean_occupancy),
            format!("{:.3}", r.padding_waste),
            r.adaptive_closes.to_string(),
        ]);
    }
    t
}

/// Render one report as the flat JSON object shape `BENCH_pipeline.json`
/// carries (the bench-gate field scanner reads it).
pub fn json_record(r: &ScenarioReport) -> String {
    format!(
        "{{\n  \"bench\": \"loadgen_{}\",\n  \"scenario\": \"{}\",\n  \
         \"policy\": \"{}\",\n  \"requests\": {},\n  \"completed\": {},\n  \
         \"shed\": {},\n  \"throughput_lps\": {:.1},\n  \"p50_ms\": {:.3},\n  \
         \"p95_ms\": {:.3},\n  \"p99_ms\": {:.3},\n  \"queue_p99_ms\": {:.3},\n  \
         \"exec_p99_ms\": {:.3},\n  \"occupancy\": {:.4},\n  \
         \"padding_waste\": {:.4},\n  \"adaptive_closes\": {}\n}}",
        r.scenario,
        r.scenario,
        r.policy,
        r.requests,
        r.completed,
        r.shed(),
        r.throughput_lps,
        r.p50_ms,
        r.p95_ms,
        r.p99_ms,
        r.queue_p99_ms,
        r.exec_p99_ms,
        r.mean_occupancy,
        r.padding_waste,
        r.adaptive_closes,
    )
}

/// The one splitter for `BENCH_pipeline.json`-shaped files, re-exported
/// from [`crate::util::flatjson`]: `bench_gate`'s field scanner and the
/// tune profile loader walk the same bodies, so the parsers cannot drift.
pub use crate::util::flatjson::split_flat_objects;

/// Merge a bench family's records into `BENCH_pipeline.json`: keep every
/// existing record whose `bench` name does not start with `prefix` (the
/// other harnesses' rows), replace any stale same-family rows, append the
/// new ones. Idempotent — re-running a harness never duplicates rows.
/// (`solver_micro` rewrites the file wholesale, so run it first, as CI's
/// bench job does.)
pub fn merge_prefixed_records(
    path: &Path,
    records: &[String],
    prefix: &str,
) -> anyhow::Result<()> {
    let mut bodies: Vec<String> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        for obj in split_flat_objects(&text) {
            let is_family =
                obj.contains("\"bench\"") && obj.contains(&format!("\"{prefix}"));
            if !is_family {
                bodies.push(format!("{{\n  {obj}\n}}"));
            }
        }
    }
    bodies.extend(records.iter().cloned());
    std::fs::write(path, crate::util::flatjson::render_array(&bodies))
        .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", path.display()))
}

/// [`merge_prefixed_records`] for the loadgen family (`loadgen_*`).
pub fn merge_into_bench_json(path: &Path, records: &[String]) -> anyhow::Result<()> {
    merge_prefixed_records(path, records, "loadgen_")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(name: &'static str) -> ScenarioReport {
        ScenarioReport {
            scenario: name,
            policy: "adaptive",
            requests: 100,
            completed: 90,
            shed_interactive: 2,
            shed_bulk: 8,
            wall_s: 1.0,
            throughput_lps: 90.0,
            p50_ms: 1.0,
            p95_ms: 2.0,
            p99_ms: 3.0,
            queue_p50_ms: 0.2,
            queue_p95_ms: 0.6,
            queue_p99_ms: 0.8,
            exec_p99_ms: 1.5,
            mean_occupancy: 0.7,
            padding_waste: 0.2,
            adaptive_closes: 4,
            observations: vec![Observation {
                class_m: 16,
                problems: 90,
                busy_ns: 90_000.0,
                samples: 9,
            }],
            snapshot: Snapshot::default(),
        }
    }

    #[test]
    fn table_has_the_gated_columns() {
        let t = table(&[report("bursty")]);
        assert!(t.header.iter().any(|h| h == "p99_ms"));
        assert!(t.header.iter().any(|h| h == "shed"));
        let md = t.to_markdown();
        assert!(md.contains("bursty"));
        assert!(md.contains("10")); // shed total = 2 + 8
    }

    #[test]
    fn json_record_is_scannable() {
        let rec = json_record(&report("flood"));
        assert!(rec.contains("\"bench\": \"loadgen_flood\""));
        assert!(rec.contains("\"throughput_lps\": 90.0"));
        assert!(rec.contains("\"shed\": 10"));
    }

    #[test]
    fn merge_keeps_foreign_records_and_replaces_loadgen() {
        let dir = std::env::temp_dir().join(format!(
            "loadgen_merge_test_{}_{}",
            std::process::id(),
            std::thread::current().name().unwrap_or("t").len()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_pipeline.json");
        std::fs::write(
            &path,
            "[\n{\n  \"bench\": \"pipeline_cpu\",\n  \"throughput_lps\": 10.0\n},\n\
             {\n  \"bench\": \"loadgen_flood\",\n  \"throughput_lps\": 1.0\n}\n]\n",
        )
        .unwrap();
        let fresh = vec![json_record(&report("flood")), json_record(&report("bursty"))];
        merge_into_bench_json(&path, &fresh).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("pipeline_cpu"));
        assert!(text.contains("loadgen_bursty"));
        // The stale flood row (1.0 LPs/s) was replaced by the fresh one.
        assert_eq!(text.matches("loadgen_flood").count(), 1);
        assert!(text.contains("\"throughput_lps\": 90.0"));
        // Idempotent: merging again changes nothing.
        merge_into_bench_json(&path, &fresh).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), text);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn class_observations_apportion_execute_time_by_live_rows() {
        use crate::coordinator::metrics::ExecTimingTotals;
        use crate::coordinator::ClassPadding;
        let snap = Snapshot {
            submitted: 12,
            solved: 12,
            infeasible: 0,
            rejected: 0,
            shed_interactive: 0,
            shed_bulk: 0,
            batches: 3,
            mean_occupancy: 0.8,
            pipeline_depth: 2,
            closes: Default::default(),
            queue_wait_p50_ns: 0,
            queue_wait_p95_ns: 0,
            queue_wait_p99_ns: 0,
            exec_p50_ns: 0,
            exec_p95_ns: 0,
            exec_p99_ns: 0,
            exec_mean_ns: 0.0,
            timing: ExecTimingTotals { execute_ns: 1_000_000, ..Default::default() },
            per_shard: Vec::new(),
            padding: vec![
                // 8 slots x 16 rows, 96 live rows over 2 batches.
                ClassPadding { class_m: 16, batches: 2, rows_used: 96, rows_total: 128 },
                // 4 slots x 64 rows, 224 live rows over 1 batch.
                ClassPadding { class_m: 64, batches: 1, rows_used: 224, rows_total: 256 },
                // Pre-sized zero row: no traffic, no observation.
                ClassPadding { class_m: 256, ..Default::default() },
            ],
            queue_depths: Vec::new(),
            ..Default::default()
        };
        let obs = class_observations(&snap);
        assert_eq!(obs.len(), 2, "silent classes yield nothing: {obs:?}");
        assert_eq!(obs[0].class_m, 16);
        assert_eq!(obs[0].problems, 8);
        assert_eq!(obs[0].samples, 2);
        assert!((obs[0].busy_ns - 1_000_000.0 * 96.0 / 320.0).abs() < 1e-6);
        assert_eq!(obs[1].class_m, 64);
        assert_eq!(obs[1].problems, 4);
        assert!((obs[1].busy_ns - 1_000_000.0 * 224.0 / 320.0).abs() < 1e-6);
        // An idle run (no execute time) produces no observations at all.
        let idle = Snapshot { timing: ExecTimingTotals::default(), ..snap };
        assert!(class_observations(&idle).is_empty());
    }

    #[test]
    fn absorb_into_profile_feeds_homogeneous_mixes_only() {
        let dir = std::env::temp_dir()
            .join(format!("loadgen_absorb_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("TUNE_profile.json");
        let reports = vec![report("poisson"), report("bursty")];
        // Heterogeneous mix: attribution is ambiguous, nothing written.
        let hetero = vec![BackendSpec::SimdCpu { threads: 2 }, BackendSpec::Cpu];
        assert_eq!(absorb_into_profile(&path, &hetero, &reports).unwrap(), None);
        assert!(!path.exists());
        // Homogeneous mix (same kind on every shard): observations land
        // on that kind's fit, created from scratch here.
        let homo = vec![
            BackendSpec::SimdCpu { threads: 2 },
            BackendSpec::SimdCpu { threads: 2 },
        ];
        assert_eq!(absorb_into_profile(&path, &homo, &reports).unwrap(), Some(2));
        let profile = Profile::load(&path).unwrap();
        let fit = profile.backend("simd-cpu:2", Variant::Rgb).expect("fit created");
        let c = fit.class(16).expect("observed class fitted");
        // Both reports observe 1000 ns/problem; the blended rate is it.
        assert!((c.per_problem_ns - 1_000.0).abs() < 0.1, "rate {}", c.per_problem_ns);
        assert_eq!(c.points, 18, "9 batch samples per report");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn split_flat_objects_handles_trailing_commas() {
        let objs = split_flat_objects("[\n{ \"a\": 1 },\n{ \"b\": 2 }\n]\n");
        assert_eq!(objs.len(), 2);
        assert!(objs[0].contains("\"a\""));
    }
}

//! Cross-request reuse bench: the headline numbers for the result cache
//! and warm-started Seidel layer.
//!
//! Two measurements, one artifact:
//!
//! * **sim steps/second** — the clearance crowd ([`World::crossing_groups`])
//!   stepped on the multicore CPU baseline, cold vs warm-started
//!   ([`World::with_warm_start`]). Warm steps skip the Seidel solve for
//!   every agent whose LP is bit-identical to its previous tick
//!   (certified hints), so the ratio is the end-to-end payoff of temporal
//!   coherence — `sim_steps_cold` / `sim_steps_warm` rows.
//! * **cache hit-rate sweep** — duplicate-rich request streams at several
//!   coherence levels (the fraction of requests that exactly repeat an
//!   earlier one) driven through a [`Service`] with the content-addressed
//!   result cache enabled, vs a cache-disabled reference run over the
//!   same stream. Reports measured hit rate, throughput, and whether the
//!   cached replies are **bit-identical** to the uncached ones (they must
//!   be: hits replay stored solution bits, and the content-keyed wire
//!   format makes every cold solve independent of batch composition) —
//!   `cache_c{level}` rows.
//!
//! Results go to `CACHE_table.md` ([`render_markdown`]) and
//! `BENCH_pipeline.json` (flat records via
//! [`merge_prefixed_records`](crate::bench::loadgen::merge_prefixed_records),
//! prefixes `sim_steps_` and `cache_`) for the perf gate.

use std::path::Path;
use std::time::Instant;

use crate::coordinator::{BackendSpec, Config, Service};
use crate::gen;
use crate::lp::{Problem, Solution};
use crate::runtime::PipelineDepth;
use crate::sim::{World, WorldParams};
use crate::util::{Rng, Table};

/// Reuse-bench knobs (crowd size + request stream shape).
#[derive(Clone, Debug)]
pub struct ReuseOpts {
    /// Crowd size for the sim-steps measurement.
    pub agents: usize,
    /// Steps per sim run.
    pub steps: usize,
    /// CPU threads for the sim batch solve.
    pub threads: usize,
    /// Requests per cache-sweep level.
    pub requests: usize,
    /// Result-cache capacity for the cached runs.
    pub cache_capacity: usize,
    /// Coherence levels to sweep: fraction of requests that exactly
    /// repeat an earlier request in the stream.
    pub coherence: Vec<f64>,
    pub seed: u64,
}

impl Default for ReuseOpts {
    fn default() -> Self {
        ReuseOpts {
            agents: 192,
            steps: 120,
            threads: 4,
            requests: 4_000,
            cache_capacity: 8_192,
            coherence: vec![0.0, 0.5, 0.9],
            seed: 0x2E05E,
        }
    }
}

/// One sim run's measured stepping rate.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// `"cold"` or `"warm"`.
    pub mode: &'static str,
    pub agents: usize,
    pub steps: usize,
    pub wall_s: f64,
    pub steps_per_s: f64,
    /// LP solves represented per second (agents x steps / wall; warm
    /// runs count certified skips as served solves — that is the point).
    pub throughput_lps: f64,
    /// Total certified warm hits across the run (0 on the cold path).
    pub warm_hits: usize,
}

/// One coherence level's measured cache behaviour.
#[derive(Clone, Debug)]
pub struct CacheReport {
    /// Requested duplicate fraction (the stream generator's knob).
    pub coherence: f64,
    pub requests: usize,
    pub completed: usize,
    /// Submit-path cache counters from the service snapshot.
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Measured hit rate, hits / (hits + misses).
    pub hit_rate: f64,
    pub wall_s: f64,
    pub throughput_lps: f64,
    /// Cached replies bitwise equal to the cache-disabled reference run.
    pub bit_identical: bool,
}

/// Step the clearance crowd `opts.steps` times on the CPU baseline and
/// measure steps/second; `warm` switches on the warm-start path.
pub fn run_sim(opts: &ReuseOpts, warm: bool) -> anyhow::Result<SimReport> {
    let mut rng = Rng::new(opts.seed);
    let mut world = World::crossing_groups(&mut rng, opts.agents, WorldParams::default());
    if warm {
        world = world.with_warm_start();
    }
    let t0 = Instant::now();
    let mut warm_hits = 0usize;
    let mut lps = 0usize;
    for _ in 0..opts.steps {
        let stats = world.step_cpu(opts.threads, &mut rng)?;
        warm_hits += stats.warm_hits;
        lps += stats.lps;
    }
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    Ok(SimReport {
        mode: if warm { "warm" } else { "cold" },
        agents: opts.agents,
        steps: opts.steps,
        wall_s,
        steps_per_s: opts.steps as f64 / wall_s,
        throughput_lps: lps as f64 / wall_s,
        warm_hits,
    })
}

/// Build a duplicate-rich stream: each request exactly repeats a random
/// earlier one with probability `coherence`, else draws a fresh feasible
/// LP (sizes 6..=32). Deterministic in the seed.
pub fn coherent_stream(rng: &mut Rng, n: usize, coherence: f64) -> Vec<Problem> {
    let mut out: Vec<Problem> = Vec::with_capacity(n);
    for i in 0..n {
        if i > 0 && rng.f64() < coherence {
            let j = rng.below(out.len());
            let dup = out[j].clone();
            out.push(dup);
        } else {
            let m = 6 + rng.below(27);
            out.push(gen::feasible(rng, m));
        }
    }
    out
}

fn solutions_bit_equal(a: &[Solution], b: &[Solution]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.status == y.status
                && x.point[0].to_bits() == y.point[0].to_bits()
                && x.point[1].to_bits() == y.point[1].to_bits()
        })
}

fn serve_config(opts: &ReuseOpts, cached: bool) -> Config {
    Config {
        backends: vec![
            BackendSpec::BatchCpu { threads: 2 },
            BackendSpec::BatchCpu { threads: 2 },
            BackendSpec::Cpu,
        ],
        depth: PipelineDepth::new(2),
        // Closed-loop drive: admit the whole stream, nothing sheds.
        max_queue: opts.requests + 64,
        cache_capacity: if cached { opts.cache_capacity } else { 0 },
        cache_eps: 0.0,
        warm_start: cached,
        ..Config::default()
    }
}

/// Drive one coherence level: serve the same stream through a cached
/// (capacity + warm hints on) and an uncached service, compare the reply
/// bits, and read the cache counters off the cached run's snapshot.
pub fn run_cache_level(
    artifact_dir: &Path,
    coherence: f64,
    opts: &ReuseOpts,
) -> anyhow::Result<CacheReport> {
    let mut rng = Rng::new(opts.seed ^ 0xC0_4E7E);
    let stream = coherent_stream(&mut rng, opts.requests, coherence);

    // Reference first: cache disabled is the historical byte-for-byte path.
    let reference = Service::start(artifact_dir, serve_config(opts, false))?;
    let expected = reference.solve_all(&stream)?;
    reference.shutdown();

    let service = Service::start(artifact_dir, serve_config(opts, true))?;
    let t0 = Instant::now();
    let got = service.solve_all(&stream)?;
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    let snap = service.metrics().snapshot();
    service.shutdown();

    Ok(CacheReport {
        coherence,
        requests: opts.requests,
        completed: got.len(),
        hits: snap.cache_hits,
        misses: snap.cache_misses,
        evictions: snap.cache_evictions,
        hit_rate: snap.cache_hit_rate(),
        wall_s,
        throughput_lps: got.len() as f64 / wall_s,
        bit_identical: solutions_bit_equal(&got, &expected),
    })
}

/// The `CACHE_table.md` body: the sim steps/second table (with the
/// warm/cold improvement line the acceptance gate reads), then the
/// hit-rate sweep table.
pub fn render_markdown(sims: &[SimReport], sweeps: &[CacheReport]) -> String {
    let mut t = Table::new(&["mode", "agents", "steps", "steps_per_s", "LPs/s", "warm_hits"]);
    for r in sims {
        t.push_row(vec![
            r.mode.to_string(),
            r.agents.to_string(),
            r.steps.to_string(),
            format!("{:.1}", r.steps_per_s),
            format!("{:.0}", r.throughput_lps),
            r.warm_hits.to_string(),
        ]);
    }
    let mut out = String::from("## sim steps/second: cold vs warm-started clearance crowd\n\n");
    out.push_str(&t.to_markdown());
    let cold = sims.iter().find(|r| r.mode == "cold");
    let warm = sims.iter().find(|r| r.mode == "warm");
    if let (Some(c), Some(w)) = (cold, warm) {
        out.push_str(&format!(
            "\nwarm-start improvement: {:.2}x steps/s ({:.1} -> {:.1})\n",
            w.steps_per_s / c.steps_per_s.max(1e-9),
            c.steps_per_s,
            w.steps_per_s,
        ));
    }

    let mut t = Table::new(&[
        "coherence",
        "requests",
        "completed",
        "hits",
        "misses",
        "evictions",
        "hit_rate",
        "LPs/s",
        "bit_identical",
    ]);
    for r in sweeps {
        t.push_row(vec![
            format!("{:.2}", r.coherence),
            r.requests.to_string(),
            r.completed.to_string(),
            r.hits.to_string(),
            r.misses.to_string(),
            r.evictions.to_string(),
            format!("{:.3}", r.hit_rate),
            format!("{:.0}", r.throughput_lps),
            r.bit_identical.to_string(),
        ]);
    }
    out.push_str("\n## cache hit-rate sweep over coherence levels\n\n");
    out.push_str(&t.to_markdown());
    out
}

/// Render one sim run as a flat `BENCH_pipeline.json` record
/// (`sim_steps_cold` / `sim_steps_warm`).
pub fn sim_json_record(r: &SimReport) -> String {
    format!(
        "{{\n  \"bench\": \"sim_steps_{}\",\n  \"agents\": {},\n  \
         \"steps\": {},\n  \"steps_per_s\": {:.1},\n  \"warm_hits\": {},\n  \
         \"throughput_lps\": {:.1}\n}}",
        r.mode, r.agents, r.steps, r.steps_per_s, r.warm_hits, r.throughput_lps,
    )
}

/// Render one sweep level as a flat record (`cache_c00` / `cache_c50` /
/// `cache_c90` for coherence 0.0 / 0.5 / 0.9).
pub fn cache_json_record(r: &CacheReport) -> String {
    format!(
        "{{\n  \"bench\": \"cache_c{:02}\",\n  \"coherence\": {:.2},\n  \
         \"requests\": {},\n  \"completed\": {},\n  \"hits\": {},\n  \
         \"misses\": {},\n  \"evictions\": {},\n  \"hit_rate\": {:.4},\n  \
         \"bit_identical\": {},\n  \"throughput_lps\": {:.1}\n}}",
        (r.coherence * 100.0).round() as u32,
        r.coherence,
        r.requests,
        r.completed,
        r.hits,
        r.misses,
        r.evictions,
        r.hit_rate,
        r.bit_identical,
        r.throughput_lps,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::content_key;

    #[test]
    fn coherent_stream_repeats_the_requested_fraction() {
        let mut rng = Rng::new(7);
        let stream = coherent_stream(&mut rng, 400, 0.6);
        assert_eq!(stream.len(), 400);
        let mut seen = std::collections::HashSet::new();
        let dups = stream
            .iter()
            .filter(|p| !seen.insert(content_key(p, 0.0)))
            .count();
        let frac = dups as f64 / 400.0;
        assert!((0.4..0.8).contains(&frac), "duplicate fraction {frac}");
        // Deterministic in the seed.
        let again = coherent_stream(&mut Rng::new(7), 400, 0.6);
        assert!(stream
            .iter()
            .zip(&again)
            .all(|(a, b)| content_key(a, 0.0) == content_key(b, 0.0)));
        // Coherence 0 means every request is fresh.
        let fresh = coherent_stream(&mut Rng::new(9), 200, 0.0);
        let mut keys = std::collections::HashSet::new();
        assert!(fresh.iter().all(|p| keys.insert(content_key(p, 0.0))));
    }

    #[test]
    fn json_records_are_scannable() {
        let sim = SimReport {
            mode: "warm",
            agents: 64,
            steps: 10,
            wall_s: 1.0,
            steps_per_s: 10.0,
            throughput_lps: 640.0,
            warm_hits: 123,
        };
        let rec = sim_json_record(&sim);
        assert!(rec.contains("\"bench\": \"sim_steps_warm\""));
        assert!(rec.contains("\"throughput_lps\": 640.0"));
        let sweep = CacheReport {
            coherence: 0.9,
            requests: 100,
            completed: 100,
            hits: 80,
            misses: 20,
            evictions: 0,
            hit_rate: 0.8,
            wall_s: 1.0,
            throughput_lps: 100.0,
            bit_identical: true,
        };
        let rec = cache_json_record(&sweep);
        assert!(rec.contains("\"bench\": \"cache_c90\""));
        assert!(rec.contains("\"hit_rate\": 0.8000"));
        assert!(rec.contains("\"bit_identical\": true"));
    }

    #[test]
    fn markdown_carries_the_improvement_line() {
        let cold = SimReport {
            mode: "cold",
            agents: 64,
            steps: 10,
            wall_s: 2.0,
            steps_per_s: 5.0,
            throughput_lps: 320.0,
            warm_hits: 0,
        };
        let warm = SimReport { mode: "warm", steps_per_s: 10.0, warm_hits: 400, ..cold.clone() };
        let sweep = CacheReport {
            coherence: 0.5,
            requests: 100,
            completed: 100,
            hits: 40,
            misses: 60,
            evictions: 2,
            hit_rate: 0.4,
            wall_s: 1.0,
            throughput_lps: 100.0,
            bit_identical: true,
        };
        let md = render_markdown(&[cold, warm], &[sweep]);
        assert!(md.contains("warm-start improvement: 2.00x"));
        assert!(md.contains("hit_rate"));
        assert!(md.contains("bit_identical"));
        assert!(md.contains("0.400"));
    }
}

//! Benchmark harness and figure-reproduction sweeps.
//!
//! * [`harness`]    -- warmup/measure micro-bench core (criterion stand-in).
//! * [`figures`]    -- Figures 3, 4, 5, 7 sweep runners over the engine +
//!   CPU baselines.
//! * [`contention`] -- Figure 6 reduction-vs-contention mechanisms.
//! * [`imbalance`]  -- Figures 1/2 warp work-unit distribution statistics.
//! * [`ablations`]  -- randomization / padding / batch-mix / batch-window
//!   ablations of the design choices.
//! * [`loadgen`]    -- open-loop latency-under-load scenario driver over
//!   the serving layer (p50/p95/p99, queue-wait vs execute split, shed).
//! * [`calibration`] -- tune-profile accuracy harness (predicted vs
//!   measured batch cost per backend × class × occupancy).
//! * [`reuse`]      -- cross-request reuse: sim steps/second cold vs
//!   warm-started, plus cache hit-rate sweeps over coherence levels.

pub mod ablations;
pub mod calibration;
pub mod contention;
pub mod figures;
pub mod harness;
pub mod imbalance;
pub mod loadgen;
pub mod reuse;

pub use harness::{bench, report_line, BenchOpts, BenchResult};

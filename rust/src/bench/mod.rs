//! Benchmark harness and figure-reproduction sweeps.
//!
//! * [`harness`]    -- warmup/measure micro-bench core (criterion stand-in).
//! * [`figures`]    -- Figures 3, 4, 5, 7 sweep runners over the engine +
//!   CPU baselines.
//! * [`contention`] -- Figure 6 reduction-vs-contention mechanisms.
//! * [`imbalance`]  -- Figures 1/2 warp work-unit distribution statistics.
//! * [`ablations`]  -- randomization / padding / batch-mix / batch-window
//!   ablations of the design choices.

pub mod ablations;
pub mod contention;
pub mod figures;
pub mod harness;
pub mod imbalance;

pub use harness::{bench, report_line, BenchOpts, BenchResult};

//! Calibration-accuracy harness: profile a backend mix, then measure how
//! well the fitted `setup_ns + per_problem_ns` models predict fresh batch
//! costs — including at half occupancy, deliberately off the fitted grid.
//!
//! The product is the **calibration-accuracy table** (predicted vs
//! measured busy time per (backend, class, occupancy) cell) rendered as
//! markdown (`TUNE_table.md`, a CI artifact) and as flat `tune_*` records
//! merged into `BENCH_pipeline.json` next to the solver_micro and loadgen
//! rows, so the perf gate tracks the calibration path's throughput like
//! any other bench.

use std::path::Path;

use crate::coordinator::BackendSpec;
use crate::runtime::{Manifest, Variant};
use crate::tune::{profile_backend, validate_fit, AccuracyRow, Profile, ProfilerOpts};
use crate::util::Table;

/// One full profile-then-validate pass over a backend mix.
#[derive(Clone, Debug)]
pub struct CalibrationReport {
    pub profile: Profile,
    pub rows: Vec<AccuracyRow>,
    /// Aggregate validation throughput (problems / measured second) —
    /// the gated number.
    pub throughput_lps: f64,
    /// Mean absolute relative prediction error across cells.
    pub mean_abs_err: f64,
}

/// Profile each **distinct** backend kind in `specs` over the variant's
/// bucket grid, then re-measure at full and half occupancy and compare
/// against the fits. Engine-free mixes run against the synthetic CPU
/// inventory (no artifacts), mirroring the service's fallback.
pub fn run(
    specs: &[BackendSpec],
    artifact_dir: &Path,
    variant: Variant,
    opts: &ProfilerOpts,
) -> anyhow::Result<CalibrationReport> {
    anyhow::ensure!(!specs.is_empty(), "no backends to calibrate");
    let needs_engine = specs.iter().any(|s| matches!(s, BackendSpec::Engine));
    let manifest = Manifest::load_or_cpu_fallback(artifact_dir, needs_engine)?;
    let keys = BackendSpec::distinct_keys(specs);

    let mut profile = Profile::default();
    let mut rows: Vec<AccuracyRow> = Vec::new();
    for key in &keys {
        let spec = BackendSpec::parse(key)?;
        let mut backend = spec.build(artifact_dir)?;
        let fit = profile_backend(backend.as_mut(), key, &manifest, variant, opts)?;
        rows.extend(validate_fit(backend.as_mut(), &fit, &manifest, variant, opts)?);
        profile.upsert(fit);
    }

    let problems: u64 = rows.iter().map(|r| r.problems as u64).sum();
    let measured_ns: u64 = rows.iter().map(|r| r.measured_ns).sum();
    let mean_abs_err = if rows.is_empty() {
        0.0
    } else {
        rows.iter().map(|r| r.rel_err().abs()).sum::<f64>() / rows.len() as f64
    };
    Ok(CalibrationReport {
        profile,
        rows,
        throughput_lps: problems as f64 / (measured_ns.max(1) as f64 / 1e9),
        mean_abs_err,
    })
}

/// The predicted-vs-measured table, one row per validation cell.
pub fn table(rows: &[AccuracyRow]) -> Table {
    let mut t = Table::new(&[
        "backend",
        "class_m",
        "problems",
        "predicted_us",
        "measured_us",
        "rel_err",
    ]);
    for r in rows {
        t.push_row(vec![
            r.backend.clone(),
            r.class_m.to_string(),
            r.problems.to_string(),
            format!("{:.1}", r.predicted_ns as f64 / 1e3),
            format!("{:.1}", r.measured_ns as f64 / 1e3),
            format!("{:+.1}%", 100.0 * r.rel_err()),
        ]);
    }
    t
}

/// Flat `tune_*` records for `BENCH_pipeline.json`: one gated summary
/// (`tune_calibration`, carrying the validation throughput) plus one
/// `tune_accuracy` record per cell (data-only — no `throughput_lps`, so
/// the gate's scanner skips them).
pub fn json_records(report: &CalibrationReport) -> Vec<String> {
    let mut out = vec![format!(
        "{{\n  \"bench\": \"tune_calibration\",\n  \"cells\": {},\n  \
         \"throughput_lps\": {:.1},\n  \"mean_abs_rel_err\": {:.4}\n}}",
        report.rows.len(),
        report.throughput_lps,
        report.mean_abs_err,
    )];
    for r in &report.rows {
        out.push(format!(
            "{{\n  \"bench\": \"tune_accuracy\",\n  \"backend\": \"{}\",\n  \
             \"class_m\": {},\n  \"problems\": {},\n  \"predicted_ns\": {},\n  \
             \"measured_ns\": {},\n  \"rel_err\": {:.4}\n}}",
            r.backend,
            r.class_m,
            r.problems,
            r.predicted_ns,
            r.measured_ns,
            r.rel_err(),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_only_calibration_runs_without_artifacts() {
        let specs = vec![BackendSpec::BatchCpu { threads: 2 }, BackendSpec::Cpu];
        let opts = ProfilerOpts { runs: 1, warmup: 0, max_batch: 64, seed: 9 };
        let report = run(
            &specs,
            Path::new("definitely-missing-artifact-dir"),
            Variant::Rgb,
            &opts,
        )
        .expect("CPU-only calibration needs no artifacts");
        assert_eq!(report.profile.backends.len(), 2);
        assert!(!report.rows.is_empty());
        assert!(report.throughput_lps > 0.0);
        // Full + half occupancy per (backend, class) cell.
        let t = table(&report.rows);
        assert!(t.header.iter().any(|h| h == "predicted_us"));
        let records = json_records(&report);
        assert!(records[0].contains("\"bench\": \"tune_calibration\""));
        assert!(records[0].contains("throughput_lps"));
        assert!(records.len() == report.rows.len() + 1);
        assert!(records[1].contains("\"bench\": \"tune_accuracy\""));
        // Accuracy records carry no gated throughput field.
        assert!(!records[1].contains("throughput_lps"));
    }

    #[test]
    fn duplicate_specs_profile_once() {
        let specs = vec![BackendSpec::Cpu, BackendSpec::Cpu, BackendSpec::Cpu];
        let opts = ProfilerOpts { runs: 1, warmup: 0, max_batch: 32, seed: 5 };
        let report = run(
            &specs,
            Path::new("definitely-missing-artifact-dir"),
            Variant::Rgb,
            &opts,
        )
        .unwrap();
        assert_eq!(report.profile.backends.len(), 1, "keyed by backend kind");
    }
}

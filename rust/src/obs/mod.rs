//! Observability: bounded per-request span recording, Chrome-trace /
//! Prometheus export, and SLO burn-rate tracking.
//!
//! The paper's whole argument is about where time goes — occupancy is
//! what buys the batched-LP speedups — so the serving stack needs to
//! answer *why* a percentile moved, not just *that* it moved. This
//! module is that layer, in three pieces:
//!
//! * [`spans`] — a bounded, sampled span recorder. Every pipeline stage
//!   (admit → enqueue → batch-close → stage → steal → execute → unpack
//!   → reply) stamps events for every Nth sampled request plus every
//!   batch, into a fixed-capacity ring. With the recorder absent the
//!   hot path does no work at all; with it present but a request
//!   unsampled, admission costs one atomic increment.
//! * [`export`] — renders the ring as Chrome trace-event JSON (loadable
//!   in Perfetto / chrome://tracing: one track per shard plus a
//!   per-request flow track) and renders a metrics [`Snapshot`] as a
//!   Prometheus-style text exposition with explicit histogram buckets.
//! * [`slo`] — per-(size class × deadline class) SLO burn-rate gauges:
//!   the violation fraction over short and long EWMA windows, fed from
//!   the same per-request wait records the close policy produces.
//!
//! [`Snapshot`]: crate::coordinator::metrics::Snapshot

pub mod export;
pub mod slo;
pub mod spans;

pub use export::{
    chrome_trace_json, prometheus_exposition, write_chrome_trace, write_metrics_exposition,
};
pub use slo::{ClassBurn, SloTracker};
pub use spans::{Phase, SpanEvent, SpanRecorder};

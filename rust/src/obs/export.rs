//! Exporters: the span ring as Chrome trace-event JSON (Perfetto /
//! chrome://tracing) and a metrics [`Snapshot`] as a Prometheus-style
//! text exposition.
//!
//! Both renderers are pure string builders over frozen inputs — no
//! locks are held while formatting, and (as everywhere in this crate)
//! the JSON is hand-rolled against the stable subset of the formats we
//! need, not a serde dependency.
//!
//! # Chrome trace layout
//!
//! One process (`pid` 1). Track (`tid`) 0 is the **requests** track:
//! every sampled request's lifecycle instants land there, joined by a
//! flow (`ph:"s"` at admit → `ph:"f"` at reply, `id` = request id) so
//! Perfetto draws an arrow from admission to reply. Tracks 1..=S are
//! the **shard** tracks, named `shard N [backend]`: batch-scope events
//! land on the shard that performed the stage, with timed phases
//! (staged/executed) as complete (`"X"`) slices whose width is the
//! stage duration. Timestamps are microseconds from the recorder epoch
//! (the trace-event format's native unit).

use std::path::Path;

use crate::coordinator::metrics::Snapshot;
use crate::obs::spans::{SpanEvent, SpanRecorder};

fn ts_us(ns: u64) -> String {
    format!("{:.3}", ns as f64 / 1e3)
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn thread_name_row(tid: usize, name: &str) -> String {
    format!(
        "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\
         \"args\":{{\"name\":\"{}\"}}}}",
        json_escape(name)
    )
}

fn event_args(e: &SpanEvent) -> String {
    let mut args = Vec::new();
    if let Some(r) = e.req {
        args.push(format!("\"req\":{r}"));
    }
    if let Some(b) = e.batch {
        args.push(format!("\"batch\":{b}"));
    }
    if let Some(s) = e.shard {
        args.push(format!("\"shard\":{s}"));
    }
    if e.n > 0 {
        args.push(format!("\"n\":{}", e.n));
    }
    args.push(format!("\"class_m\":{}", e.class_m));
    if e.stolen {
        args.push("\"stolen\":true".to_string());
    }
    format!("{{{}}}", args.join(","))
}

/// Render the recorder's ring as a complete Chrome trace-event JSON
/// document (the `{"traceEvents": [...]}` object form).
pub fn chrome_trace_json(rec: &SpanRecorder) -> String {
    let names = rec.shard_names();
    let events = rec.events();
    // Every named shard gets a track even when idle; events from shards
    // beyond the named range still get an (unnamed) track.
    let mut shards = names.len();
    for e in &events {
        if let Some(s) = e.shard {
            shards = shards.max(s as usize + 1);
        }
    }

    let mut rows: Vec<String> = Vec::with_capacity(events.len() + shards + 1);
    rows.push(thread_name_row(0, "requests"));
    for s in 0..shards {
        let label = match names.get(s) {
            Some(n) => format!("shard {s} [{n}]"),
            None => format!("shard {s}"),
        };
        rows.push(thread_name_row(s + 1, &label));
    }

    for e in &events {
        let name = e.phase.as_str();
        let args = event_args(e);
        match e.req {
            // Request-scope: instants on the requests track, plus flow
            // endpoints at admit/reply so Perfetto links the lifecycle.
            Some(req) => {
                rows.push(format!(
                    "{{\"name\":\"{name}\",\"cat\":\"req\",\"ph\":\"i\",\"s\":\"t\",\
                     \"pid\":1,\"tid\":0,\"ts\":{},\"args\":{args}}}",
                    ts_us(e.at_ns)
                ));
                let flow = match e.phase {
                    crate::obs::spans::Phase::Admitted => Some("\"ph\":\"s\""),
                    crate::obs::spans::Phase::Replied => Some("\"ph\":\"f\",\"bp\":\"e\""),
                    _ => None,
                };
                if let Some(flow) = flow {
                    rows.push(format!(
                        "{{\"name\":\"request\",\"cat\":\"req\",{flow},\"id\":{req},\
                         \"pid\":1,\"tid\":0,\"ts\":{}}}",
                        ts_us(e.at_ns)
                    ));
                }
            }
            // Batch-scope: slices (timed) or instants on the shard track.
            None => {
                let tid = e.shard.map(|s| s as usize + 1).unwrap_or(0);
                if e.dur_ns > 0 {
                    rows.push(format!(
                        "{{\"name\":\"{name}\",\"cat\":\"batch\",\"ph\":\"X\",\
                         \"pid\":1,\"tid\":{tid},\"ts\":{},\"dur\":{},\"args\":{args}}}",
                        ts_us(e.at_ns),
                        ts_us(e.dur_ns)
                    ));
                } else {
                    rows.push(format!(
                        "{{\"name\":\"{name}\",\"cat\":\"batch\",\"ph\":\"i\",\"s\":\"t\",\
                         \"pid\":1,\"tid\":{tid},\"ts\":{},\"args\":{args}}}",
                        ts_us(e.at_ns)
                    ));
                }
            }
        }
    }

    format!(
        "{{\"displayTimeUnit\":\"ms\",\"otherData\":{{\"dropped_events\":{},\
         \"sample_every\":{}}},\"traceEvents\":[\n{}\n]}}\n",
        rec.dropped(),
        rec.sample_every(),
        rows.join(",\n")
    )
}

/// Write [`chrome_trace_json`] to `path`.
pub fn write_chrome_trace(path: &Path, rec: &SpanRecorder) -> std::io::Result<()> {
    std::fs::write(path, chrome_trace_json(rec))
}

/// Escape a Prometheus label value (backslash, quote, newline).
fn label_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn sec(ns: u64) -> f64 {
    ns as f64 / 1e9
}

struct Expo {
    out: String,
}

impl Expo {
    fn family(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    fn row(&mut self, name: &str, labels: &str, value: impl std::fmt::Display) {
        if labels.is_empty() {
            self.out.push_str(&format!("{name} {value}\n"));
        } else {
            self.out.push_str(&format!("{name}{{{labels}}} {value}\n"));
        }
    }

    /// One full histogram family: cumulative `le` buckets (upper edges
    /// in seconds) plus `_sum` and `_count`.
    fn histogram(&mut self, name: &str, help: &str, h: &crate::util::HistogramSnapshot) {
        self.family(name, "histogram", help);
        let mut cum = 0u64;
        for (i, &c) in h.buckets.iter().enumerate() {
            cum += c;
            let le = sec(crate::util::HistogramSnapshot::bucket_upper_ns(i));
            self.row(&format!("{name}_bucket"), &format!("le=\"{le}\""), cum);
        }
        self.row(&format!("{name}_bucket"), "le=\"+Inf\"", h.count);
        self.row(&format!("{name}_sum"), "", sec(h.sum_ns));
        self.row(&format!("{name}_count"), "", h.count);
    }
}

/// Render a metrics [`Snapshot`] as Prometheus-style text exposition.
/// Covers every counter, gauge, and histogram the snapshot carries;
/// `shard_names` (backend key per shard) become the per-shard series'
/// `backend` label.
pub fn prometheus_exposition(snap: &Snapshot, shard_names: &[String]) -> String {
    let mut e = Expo { out: String::new() };
    let p = "batch_lp2d";

    // Request/outcome counters.
    for (name, help, v) in [
        ("submitted_total", "Requests submitted to the service.", snap.submitted),
        ("solved_total", "Problems solved (feasible or not).", snap.solved),
        ("infeasible_total", "Problems reported infeasible/unbounded.", snap.infeasible),
        ("rejected_total", "Submits rejected before queueing.", snap.rejected),
        ("cache_hits_total", "Submits answered from the result cache.", snap.cache_hits),
        ("cache_misses_total", "Cache-eligible submits that missed.", snap.cache_misses),
        ("cache_evictions_total", "Result-cache capacity evictions.", snap.cache_evictions),
        ("batches_total", "Batches executed.", snap.batches),
    ] {
        let name = format!("{p}_{name}");
        e.family(&name, "counter", help);
        e.row(&name, "", v);
    }

    let name = format!("{p}_shed_total");
    e.family(&name, "counter", "Load-shed requests by deadline class.");
    e.row(&name, "deadline=\"interactive\"", snap.shed_interactive);
    e.row(&name, "deadline=\"bulk\"", snap.shed_bulk);

    let name = format!("{p}_batch_closes_total");
    e.family(&name, "counter", "Batch closes by policy rule.");
    for (reason, v) in [
        ("full", snap.closes.full),
        ("deadline", snap.closes.deadline),
        ("idle", snap.closes.idle),
        ("cost", snap.closes.cost),
        ("flush", snap.closes.flush),
    ] {
        e.row(&name, &format!("reason=\"{reason}\""), v);
    }

    // Scalar gauges.
    let name = format!("{p}_mean_occupancy");
    e.family(&name, "gauge", "Mean batch occupancy (used/capacity).");
    e.row(&name, "", snap.mean_occupancy);
    let name = format!("{p}_pipeline_depth");
    e.family(&name, "gauge", "Configured staged-queue (pipeline ring) depth.");
    e.row(&name, "", snap.pipeline_depth);

    // Execute-side stage split.
    let name = format!("{p}_exec_stage_seconds_total");
    e.family(&name, "counter", "Summed executor time by stage.");
    for (stage, ns) in [
        ("pack", snap.timing.pack_ns),
        ("transfer", snap.timing.transfer_ns),
        ("execute", snap.timing.execute_ns),
        ("unpack", snap.timing.unpack_ns),
    ] {
        e.row(&name, &format!("stage=\"{stage}\""), sec(ns));
    }
    let name = format!("{p}_exec_critical_path_seconds_total");
    e.family(&name, "counter", "Summed executor critical-path time.");
    e.row(&name, "", sec(snap.timing.critical_path_ns));

    // The two latency histograms, explicit buckets.
    e.histogram(
        &format!("{p}_queue_wait_seconds"),
        "Per-request admission-queue wait (submit to batch close).",
        &snap.queue_wait_hist,
    );
    e.histogram(
        &format!("{p}_exec_latency_seconds"),
        "Per-batch execute-side latency (pack+transfer+execute+unpack).",
        &snap.exec_hist,
    );

    // Per-shard load split.
    let shard_label = |s: usize| -> String {
        let backend = shard_names.get(s).map(|n| label_escape(n)).unwrap_or_default();
        format!("shard=\"{s}\",backend=\"{backend}\"")
    };
    for (suffix, kind, help, get) in [
        (
            "shard_batches_total",
            "counter",
            "Batches executed per shard.",
            (|l| l.batches as f64) as fn(&crate::coordinator::metrics::ShardLoad) -> f64,
        ),
        ("shard_solved_total", "counter", "Problems solved per shard.", |l| l.solved as f64),
        ("shard_busy_seconds_total", "counter", "Busy time per shard.", |l| sec(l.busy_ns)),
        ("shard_steals_total", "counter", "Batches this shard stole.", |l| l.steals as f64),
        (
            "shard_stolen_away_total",
            "counter",
            "Batches stolen from this shard.",
            |l| l.stolen_away as f64,
        ),
        (
            "shard_dispatched_total",
            "counter",
            "Batches the weighted dispatcher targeted here.",
            |l| l.dispatched as f64,
        ),
        ("shard_weight", "gauge", "Nominal capacity weight.", |l| l.weight),
        (
            "shard_calibrated_weight",
            "gauge",
            "Calibrated dispatch weight.",
            |l| l.calibrated_weight,
        ),
    ] {
        let name = format!("{p}_{suffix}");
        e.family(&name, kind, help);
        for (s, load) in snap.per_shard.iter().enumerate() {
            e.row(&name, &shard_label(s), get(load));
        }
    }

    // Per-class padding gauges.
    let name = format!("{p}_class_batches_total");
    e.family(&name, "counter", "Batches closed per size class.");
    for c in &snap.padding {
        e.row(&name, &format!("class_m=\"{}\"", c.class_m), c.batches);
    }
    let name = format!("{p}_class_padding_waste");
    e.family(&name, "gauge", "Dead-padding fraction of class-shaped rows.");
    for c in &snap.padding {
        e.row(&name, &format!("class_m=\"{}\"", c.class_m), c.waste());
    }

    // Live admission-queue depths.
    let name = format!("{p}_queue_depth");
    e.family(&name, "gauge", "Live admission-queue depth per (class, deadline).");
    for q in &snap.queue_depths {
        e.row(&name, &format!("class_m=\"{}\",deadline=\"interactive\"", q.class_m), q.interactive);
        e.row(&name, &format!("class_m=\"{}\",deadline=\"bulk\"", q.class_m), q.bulk);
    }

    // SLO burn-rate gauges.
    let burn_label = |b: &crate::obs::slo::ClassBurn, extra: &str| -> String {
        format!(
            "class_m=\"{}\",deadline=\"{}\"{extra}",
            b.class_m,
            b.deadline_class.as_str()
        )
    };
    let name = format!("{p}_slo_burn");
    e.family(&name, "gauge", "SLO violation fraction over EWMA windows.");
    for b in &snap.burn {
        e.row(&name, &burn_label(b, ",window=\"short\""), b.short_burn);
        e.row(&name, &burn_label(b, ",window=\"long\""), b.long_burn);
    }
    let name = format!("{p}_slo_observed_total");
    e.family(&name, "counter", "Requests judged against their class SLO.");
    for b in &snap.burn {
        e.row(&name, &burn_label(b, ""), b.observed);
    }
    let name = format!("{p}_slo_violations_total");
    e.family(&name, "counter", "Requests that violated their class SLO.");
    for b in &snap.burn {
        e.row(&name, &burn_label(b, ""), b.violated);
    }
    let name = format!("{p}_slo_bound_seconds");
    e.family(&name, "gauge", "The wait bound each burn row judges against.");
    for b in &snap.burn {
        e.row(&name, &burn_label(b, ""), sec(b.slo_ns));
    }

    e.out
}

/// Write [`prometheus_exposition`] to `path`.
pub fn write_metrics_exposition(
    path: &Path,
    snap: &Snapshot,
    shard_names: &[String],
) -> std::io::Result<()> {
    std::fs::write(path, prometheus_exposition(snap, shard_names))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::admission::{CloseReason, DeadlineClass};
    use crate::coordinator::metrics::Metrics;
    use crate::obs::spans::Phase;
    use std::time::Duration;

    fn braces_balance(s: &str) -> bool {
        // No string in our output embeds unescaped braces, so a plain
        // depth count is a meaningful structural check.
        let mut depth = 0i64;
        for c in s.chars() {
            match c {
                '{' => depth += 1,
                '}' => depth -= 1,
                _ => {}
            }
            if depth < 0 {
                return false;
            }
        }
        depth == 0
    }

    fn recorded() -> SpanRecorder {
        let rec = SpanRecorder::new(256, 1);
        rec.configure_shards(&["batch-cpu".to_string(), "cpu".to_string()]);
        let req = rec.admit(16).unwrap();
        rec.request(Phase::Enqueued, req, 16);
        let b = rec.next_batch_id();
        rec.request_in_batch(Phase::BatchClosed, req, b, None, 16);
        let t0 = rec.now_ns();
        rec.batch_timed(Phase::Staged, b, 0, 4, 16, false, t0, 1_500);
        rec.batch(Phase::Stolen, b, 0, 4, 16, true);
        rec.batch_timed(Phase::Executed, b, 1, 4, 16, true, rec.now_ns(), 2_500);
        rec.batch(Phase::Unpacked, b, 1, 4, 16, true);
        rec.request_in_batch(Phase::Executed, req, b, Some(1), 16);
        rec.request_in_batch(Phase::Unpacked, req, b, Some(1), 16);
        rec.request_in_batch(Phase::Replied, req, b, Some(1), 16);
        rec
    }

    #[test]
    fn chrome_trace_structure() {
        let rec = recorded();
        let json = chrome_trace_json(&rec);
        assert!(braces_balance(&json), "unbalanced JSON:\n{json}");
        assert!(json.starts_with('{') && json.trim_end().ends_with('}'));
        assert!(json.contains("\"displayTimeUnit\":\"ms\""));
        assert!(json.contains("\"traceEvents\":["));
        // Track metadata: the requests track plus one per shard.
        assert!(json.contains("\"args\":{\"name\":\"requests\"}"));
        assert!(json.contains("\"args\":{\"name\":\"shard 0 [batch-cpu]\"}"));
        assert!(json.contains("\"args\":{\"name\":\"shard 1 [cpu]\"}"));
        // The sampled request shows >= 6 distinct lifecycle phases.
        for phase in
            ["admitted", "enqueued", "batch-closed", "executed", "unpacked", "replied"]
        {
            assert!(
                json.contains(&format!("\"name\":\"{phase}\",\"cat\":\"req\"")),
                "missing request phase {phase}"
            );
        }
        // Flow endpoints tie admit to reply.
        assert!(json.contains("\"ph\":\"s\",\"id\":1"));
        assert!(json.contains("\"ph\":\"f\",\"bp\":\"e\",\"id\":1"));
        // Timed batch phases render as complete slices with a duration.
        assert!(json.contains("\"name\":\"staged\",\"cat\":\"batch\",\"ph\":\"X\""));
        assert!(json.contains("\"dur\":1.500"));
        assert!(json.contains("\"dur\":2.500"));
        // The steal instant carries its flag; batch events name shards.
        assert!(json.contains("\"name\":\"stolen\""));
        assert!(json.contains("\"stolen\":true"));
        assert!(json.contains("\"tid\":2"));
    }

    #[test]
    fn chrome_trace_escapes_backend_names() {
        let rec = SpanRecorder::new(8, 1);
        rec.configure_shards(&["we\"ird\\name".to_string()]);
        let json = chrome_trace_json(&rec);
        assert!(json.contains("shard 0 [we\\\"ird\\\\name]"));
        assert!(braces_balance(&json));
    }

    #[test]
    fn empty_recorder_still_renders_valid_trace() {
        let rec = SpanRecorder::new(8, 4);
        let json = chrome_trace_json(&rec);
        assert!(braces_balance(&json));
        assert!(json.contains("\"sample_every\":4"));
        assert!(json.contains("\"name\":\"requests\""));
    }

    fn busy_snapshot() -> Snapshot {
        let m = Metrics::new();
        m.configure_shards(&[2.0, 1.0]);
        m.configure_classes(&[16]);
        m.configure_slos(1_000_000, 8_000_000, vec![(16, 1_000_000, 8_000_000)]);
        m.on_submit();
        m.on_close(
            16,
            DeadlineClass::Interactive,
            CloseReason::Full,
            &[Duration::from_millis(1), Duration::from_millis(5)],
            20,
        );
        m.on_steal_from(0);
        m.on_batch(
            1,
            0,
            true,
            2,
            4,
            1,
            &crate::runtime::ExecTiming {
                pack_ns: 1_000,
                transfer_ns: 2_000,
                execute_ns: 10_000,
                unpack_ns: 1_000,
                critical_path_ns: 13_000,
            },
        );
        m.set_queue_depths(&[(16, 1, 2)]);
        m.snapshot()
    }

    #[test]
    fn exposition_names_every_family() {
        let snap = busy_snapshot();
        let text =
            prometheus_exposition(&snap, &["batch-cpu".to_string(), "cpu".to_string()]);
        for family in [
            "batch_lp2d_submitted_total",
            "batch_lp2d_solved_total",
            "batch_lp2d_infeasible_total",
            "batch_lp2d_rejected_total",
            "batch_lp2d_cache_hits_total",
            "batch_lp2d_cache_misses_total",
            "batch_lp2d_cache_evictions_total",
            "batch_lp2d_batches_total",
            "batch_lp2d_shed_total",
            "batch_lp2d_batch_closes_total",
            "batch_lp2d_mean_occupancy",
            "batch_lp2d_pipeline_depth",
            "batch_lp2d_exec_stage_seconds_total",
            "batch_lp2d_exec_critical_path_seconds_total",
            "batch_lp2d_queue_wait_seconds",
            "batch_lp2d_exec_latency_seconds",
            "batch_lp2d_shard_batches_total",
            "batch_lp2d_shard_solved_total",
            "batch_lp2d_shard_busy_seconds_total",
            "batch_lp2d_shard_steals_total",
            "batch_lp2d_shard_stolen_away_total",
            "batch_lp2d_shard_dispatched_total",
            "batch_lp2d_shard_weight",
            "batch_lp2d_shard_calibrated_weight",
            "batch_lp2d_class_batches_total",
            "batch_lp2d_class_padding_waste",
            "batch_lp2d_queue_depth",
            "batch_lp2d_slo_burn",
            "batch_lp2d_slo_observed_total",
            "batch_lp2d_slo_violations_total",
            "batch_lp2d_slo_bound_seconds",
        ] {
            assert!(
                text.contains(&format!("# TYPE {family} ")),
                "missing family {family}"
            );
            assert!(text.contains(&format!("# HELP {family} ")));
        }
        // Labels carry the shard/backend identity and burn windows.
        assert!(text.contains("shard=\"1\",backend=\"cpu\""));
        assert!(text.contains("window=\"short\""));
        assert!(text.contains("deadline=\"interactive\""));
        assert!(text.contains("batch_lp2d_slo_violations_total{class_m=\"16\",deadline=\"interactive\"} 1\n"));
        assert!(text.ends_with('\n'));
    }

    #[test]
    fn histogram_buckets_are_cumulative_with_inf() {
        let snap = busy_snapshot();
        let text = prometheus_exposition(&snap, &[]);
        let mut last = 0u64;
        let mut rows = 0usize;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("batch_lp2d_queue_wait_seconds_bucket{le=") {
                let v: u64 = rest.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(v >= last, "buckets must be cumulative: {line}");
                last = v;
                rows += 1;
            }
        }
        assert!(rows > 10, "expected explicit buckets, saw {rows}");
        assert!(text.contains("batch_lp2d_queue_wait_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("batch_lp2d_queue_wait_seconds_count 2"));
        // sum = 6ms in seconds.
        assert!(text.contains("batch_lp2d_queue_wait_seconds_sum 0.006"));
    }

    #[test]
    fn label_escaping() {
        assert_eq!(label_escape("plain"), "plain");
        assert_eq!(label_escape("a\\b"), "a\\\\b");
        assert_eq!(label_escape("a\"b"), "a\\\"b");
        assert_eq!(label_escape("a\nb"), "a\\nb");
        let snap = busy_snapshot();
        let text = prometheus_exposition(&snap, &["we\"ird\\nm".to_string()]);
        assert!(text.contains("backend=\"we\\\"ird\\\\nm\""));
    }

    #[test]
    fn empty_snapshot_exposition_is_complete() {
        let text = prometheus_exposition(&Snapshot::default(), &[]);
        assert!(text.contains("batch_lp2d_submitted_total 0"));
        assert!(text.contains("batch_lp2d_queue_wait_seconds_count 0"));
        assert!(text.contains("# TYPE batch_lp2d_slo_burn gauge"));
    }
}

//! Per-(size class × deadline class) SLO burn-rate gauges.
//!
//! A *burn rate* is the fraction of recent requests violating their
//! class SLO, smoothed over a request-count EWMA window. Two windows per
//! row: a **short** window (α = 1/64 — reacts within ~a hundred
//! requests, pages fast) and a **long** window (α = 1/1024 — the budget
//! view, rides out bursts). The classic multi-window burn-rate alerting
//! recipe compares the two: short ≫ long means an incident is *starting*,
//! short ≪ long means it is *recovering*.
//!
//! The tracker is fed from the same per-request queue-wait records the
//! close policy already produces ([`Metrics::on_close`] forwards every
//! batch's waits), so it costs nothing extra on the hot path; thresholds
//! come from [`resolve_slo_table`] so the gauge judges requests by
//! exactly the bounds the admission pipeline enforces.
//!
//! [`Metrics::on_close`]: crate::coordinator::metrics::Metrics::on_close
//! [`resolve_slo_table`]: crate::coordinator::admission::resolve_slo_table

use crate::coordinator::admission::DeadlineClass;

/// Short-window EWMA factor (per request): ~64-request memory.
pub const SHORT_ALPHA: f64 = 1.0 / 64.0;
/// Long-window EWMA factor (per request): ~1024-request memory.
pub const LONG_ALPHA: f64 = 1.0 / 1024.0;

/// One row of the burn gauge: a (size class × deadline class) pair with
/// its resolved SLO, lifetime violation counts, and both windows.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClassBurn {
    pub class_m: usize,
    pub deadline_class: DeadlineClass,
    /// The wait bound this row judges against.
    pub slo_ns: u64,
    /// Lifetime requests observed.
    pub observed: u64,
    /// Lifetime SLO violations (wait > slo).
    pub violated: u64,
    /// Violation fraction over the short EWMA window, in [0, 1].
    pub short_burn: f64,
    /// Violation fraction over the long EWMA window, in [0, 1].
    pub long_burn: f64,
}

impl ClassBurn {
    /// Lifetime violation fraction (0 when nothing observed).
    pub fn lifetime_burn(&self) -> f64 {
        if self.observed == 0 {
            0.0
        } else {
            self.violated as f64 / self.observed as f64
        }
    }
}

/// The mutable gauge state. Lives inside the metrics registry's mutex,
/// so it needs no locking of its own.
#[derive(Clone, Debug)]
pub struct SloTracker {
    rows: Vec<ClassBurn>,
    /// Fallback bounds for size classes [`observe`](Self::observe)d
    /// before (or without) [`configure`](Self::configure); `u64::MAX`
    /// means "no SLO — never violated".
    default_interactive_ns: u64,
    default_bulk_ns: u64,
}

impl Default for SloTracker {
    fn default() -> Self {
        SloTracker {
            rows: Vec::new(),
            default_interactive_ns: u64::MAX,
            default_bulk_ns: u64::MAX,
        }
    }
}

fn zero_row(class_m: usize, deadline_class: DeadlineClass, slo_ns: u64) -> ClassBurn {
    ClassBurn {
        class_m,
        deadline_class,
        slo_ns,
        observed: 0,
        violated: 0,
        short_burn: 0.0,
        long_burn: 0.0,
    }
}

impl SloTracker {
    /// Install per-class thresholds: one `(class_m, interactive_ns,
    /// bulk_ns)` row per size class (the [`resolve_slo_table`] shape),
    /// plus defaults for classes outside the table. Pre-creates every
    /// row so the gauge is visible (at zero) before traffic arrives.
    ///
    /// [`resolve_slo_table`]: crate::coordinator::admission::resolve_slo_table
    pub fn configure(
        &mut self,
        default_interactive_ns: u64,
        default_bulk_ns: u64,
        table: Vec<(usize, u64, u64)>,
    ) {
        self.default_interactive_ns = default_interactive_ns;
        self.default_bulk_ns = default_bulk_ns;
        for (class_m, interactive_ns, bulk_ns) in table {
            self.row_mut(class_m, DeadlineClass::Interactive).slo_ns = interactive_ns;
            self.row_mut(class_m, DeadlineClass::Bulk).slo_ns = bulk_ns;
        }
    }

    fn row_mut(&mut self, class_m: usize, deadline_class: DeadlineClass) -> &mut ClassBurn {
        let at = self
            .rows
            .iter()
            .position(|r| r.class_m == class_m && r.deadline_class == deadline_class);
        let at = match at {
            Some(i) => i,
            None => {
                let slo_ns = match deadline_class {
                    DeadlineClass::Interactive => self.default_interactive_ns,
                    DeadlineClass::Bulk => self.default_bulk_ns,
                };
                self.rows.push(zero_row(class_m, deadline_class, slo_ns));
                // Keep rows in (class, interactive-before-bulk) order so
                // every surface renders them deterministically.
                self.rows.sort_by_key(|r| {
                    (r.class_m, r.deadline_class != DeadlineClass::Interactive)
                });
                self.rows
                    .iter()
                    .position(|r| r.class_m == class_m && r.deadline_class == deadline_class)
                    .unwrap()
            }
        };
        &mut self.rows[at]
    }

    /// Feed one request's queue wait. The first observation of a row
    /// seeds both windows at its own value (0 or 1) — a gauge born from
    /// one violation reads 1, not `alpha`.
    pub fn observe(&mut self, class_m: usize, deadline_class: DeadlineClass, wait_ns: u64) {
        let row = self.row_mut(class_m, deadline_class);
        let x = if wait_ns > row.slo_ns { 1.0 } else { 0.0 };
        if row.observed == 0 {
            row.short_burn = x;
            row.long_burn = x;
        } else {
            row.short_burn += SHORT_ALPHA * (x - row.short_burn);
            row.long_burn += LONG_ALPHA * (x - row.long_burn);
        }
        row.observed += 1;
        if x > 0.0 {
            row.violated += 1;
        }
    }

    /// Current gauge rows, ordered by (size class, interactive, bulk).
    pub fn snapshot(&self) -> Vec<ClassBurn> {
        self.rows.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_observation_seeds_both_windows() {
        let mut t = SloTracker::default();
        t.configure(1_000, 2_000, vec![(16, 1_000, 2_000)]);
        t.observe(16, DeadlineClass::Interactive, 5_000); // violation
        let rows = t.snapshot();
        let row = rows
            .iter()
            .find(|r| r.class_m == 16 && r.deadline_class == DeadlineClass::Interactive)
            .unwrap();
        assert_eq!(row.observed, 1);
        assert_eq!(row.violated, 1);
        assert_eq!(row.short_burn, 1.0);
        assert_eq!(row.long_burn, 1.0);
        assert_eq!(row.lifetime_burn(), 1.0);
    }

    #[test]
    fn windows_decay_at_their_own_rates() {
        let mut t = SloTracker::default();
        t.configure(1_000, 2_000, vec![(16, 1_000, 2_000)]);
        // One violation, then a run of meets: short forgets much faster.
        t.observe(16, DeadlineClass::Interactive, 5_000);
        for _ in 0..64 {
            t.observe(16, DeadlineClass::Interactive, 10);
        }
        let row = t.snapshot()[0];
        assert!(row.short_burn < row.long_burn);
        assert!(row.short_burn < 0.4, "short window forgot: {}", row.short_burn);
        assert!(row.long_burn > 0.9, "long window remembers: {}", row.long_burn);
        // Exact EWMA check: seeded at 1, then 64 zero updates.
        let expect_short = (1.0 - SHORT_ALPHA).powi(64);
        assert!((row.short_burn - expect_short).abs() < 1e-12);
        assert_eq!(row.observed, 65);
        assert_eq!(row.violated, 1);
    }

    #[test]
    fn wait_exactly_at_slo_is_not_a_violation() {
        let mut t = SloTracker::default();
        t.configure(1_000, 2_000, vec![(16, 1_000, 2_000)]);
        t.observe(16, DeadlineClass::Interactive, 1_000);
        let row = t.snapshot()[0];
        assert_eq!(row.violated, 0);
        assert_eq!(row.short_burn, 0.0);
    }

    #[test]
    fn deadline_classes_track_separately_with_own_bounds() {
        let mut t = SloTracker::default();
        t.configure(1_000, 2_000, vec![(16, 1_000, 2_000)]);
        // 1.5µs violates interactive (1µs) but meets bulk (2µs).
        t.observe(16, DeadlineClass::Interactive, 1_500);
        t.observe(16, DeadlineClass::Bulk, 1_500);
        let rows = t.snapshot();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].deadline_class, DeadlineClass::Interactive);
        assert_eq!(rows[0].violated, 1);
        assert_eq!(rows[1].deadline_class, DeadlineClass::Bulk);
        assert_eq!(rows[1].violated, 0);
    }

    #[test]
    fn configured_rows_are_visible_before_traffic() {
        let mut t = SloTracker::default();
        t.configure(1_000, 2_000, vec![(16, 500, 2_000), (64, 1_000, 2_000)]);
        let rows = t.snapshot();
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.observed == 0 && r.short_burn == 0.0));
        assert_eq!(rows[0].class_m, 16);
        assert_eq!(rows[0].slo_ns, 500);
        assert_eq!(rows[3].class_m, 64);
        assert_eq!(rows[3].deadline_class, DeadlineClass::Bulk);
    }

    #[test]
    fn unconfigured_class_uses_defaults() {
        let mut t = SloTracker::default();
        t.configure(1_000, 2_000, Vec::new());
        t.observe(32, DeadlineClass::Bulk, 1_500); // under the 2µs default
        t.observe(32, DeadlineClass::Bulk, 3_000); // over it
        let rows = t.snapshot();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].slo_ns, 2_000);
        assert_eq!(rows[0].observed, 2);
        assert_eq!(rows[0].violated, 1);
    }

    #[test]
    fn fully_unconfigured_tracker_never_violates() {
        let mut t = SloTracker::default();
        t.observe(16, DeadlineClass::Interactive, u64::MAX - 1);
        assert_eq!(t.snapshot()[0].violated, 0);
    }
}

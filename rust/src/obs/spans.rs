//! Bounded, sampled span recorder: the raw event store behind the
//! Perfetto export.
//!
//! Two kinds of events share one ring:
//!
//! * **Request-scope** events (`req = Some(id)`) trace one sampled
//!   request's lifecycle: [`Phase::Admitted`] → [`Phase::Enqueued`] →
//!   [`Phase::BatchClosed`] → [`Phase::Executed`] → [`Phase::Unpacked`]
//!   → [`Phase::Replied`]. Requests are sampled every-Nth at admission
//!   ([`SpanRecorder::admit`]); an unsampled request costs exactly one
//!   atomic increment and stamps nothing downstream.
//! * **Batch-scope** events (`batch = Some(id)`, `req = None`) trace
//!   every closed batch through the executor: [`Phase::Staged`],
//!   [`Phase::Stolen`] (when work stealing moved it), [`Phase::Executed`],
//!   [`Phase::Unpacked`] — each carrying the shard, batch size, size
//!   class, and steal flag. Batches are never sampled away: batch volume
//!   is `occupancy×` lower than request volume, and the shard tracks are
//!   the point of the export.
//!
//! The ring is fixed-capacity: when full, the oldest event is
//! overwritten and [`SpanRecorder::dropped`] counts the loss — recording
//! never allocates after construction and never blocks the pipeline on
//! an export. All timestamps are nanoseconds from the recorder's epoch
//! (construction time), so one serve run shares a single timeline.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// A pipeline lifecycle stage. Request-scope phases and batch-scope
/// phases share the enum — the export keys tracks off the event's
/// `req`/`batch`/`shard` fields, not the phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Request accepted and routed to a size class.
    Admitted,
    /// Request entered its (size class × deadline class) queue.
    Enqueued,
    /// The request's batch closed (the event links `req` to `batch`).
    BatchClosed,
    /// Batch packed into a staged chunk on its origin shard.
    Staged,
    /// Batch popped by a thief shard instead of its origin.
    Stolen,
    /// Batch solved on a shard (spans the backend call).
    Executed,
    /// Solutions scattered back out of the batch layout.
    Unpacked,
    /// Reply delivered to the caller.
    Replied,
}

impl Phase {
    pub fn as_str(&self) -> &'static str {
        match self {
            Phase::Admitted => "admitted",
            Phase::Enqueued => "enqueued",
            Phase::BatchClosed => "batch-closed",
            Phase::Staged => "staged",
            Phase::Stolen => "stolen",
            Phase::Executed => "executed",
            Phase::Unpacked => "unpacked",
            Phase::Replied => "replied",
        }
    }

    /// Every phase, in lifecycle order.
    pub const ALL: [Phase; 8] = [
        Phase::Admitted,
        Phase::Enqueued,
        Phase::BatchClosed,
        Phase::Staged,
        Phase::Stolen,
        Phase::Executed,
        Phase::Unpacked,
        Phase::Replied,
    ];
}

/// One recorded event. `Copy` and fixed-size by design: ring pushes are
/// a store, never an allocation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanEvent {
    /// Nanoseconds since the recorder's epoch.
    pub at_ns: u64,
    pub phase: Phase,
    /// Sampled request id (1-based) for request-scope events.
    pub req: Option<u64>,
    /// Batch id (1-based) for batch-scope events and the
    /// request-to-batch link stamped at [`Phase::BatchClosed`].
    pub batch: Option<u64>,
    /// Executor shard for batch-scope events (for [`Phase::Stolen`],
    /// the *victim* the batch was stolen from).
    pub shard: Option<u32>,
    /// Span length for timed phases (`Staged`/`Executed`/`Unpacked`);
    /// 0 renders as an instant.
    pub dur_ns: u64,
    /// Batch size (problems in the batch); 0 for request-scope events.
    pub n: u32,
    /// Size class m.
    pub class_m: u32,
    /// Whether the batch ran on a thief shard.
    pub stolen: bool,
}

#[derive(Debug, Default)]
struct Ring {
    buf: Vec<SpanEvent>,
    /// Next overwrite position once the ring is full.
    next: usize,
    dropped: u64,
}

#[derive(Debug)]
struct Shared {
    epoch: Instant,
    capacity: usize,
    sample_every: u64,
    /// Requests seen at admission (sampling counter).
    seen: AtomicU64,
    /// Sampled-request id mint.
    next_req: AtomicU64,
    /// Batch id mint.
    next_batch: AtomicU64,
    ring: Mutex<Ring>,
    /// Backend key per shard, for the export's track names.
    shard_names: Mutex<Vec<String>>,
}

/// Handle to the shared ring; clones are cheap (`Arc`) and every
/// pipeline thread holds one.
#[derive(Clone, Debug)]
pub struct SpanRecorder {
    shared: Arc<Shared>,
}

impl SpanRecorder {
    /// A recorder holding at most `capacity` events, sampling every
    /// `sample_every`-th request (1 = trace every request). Both are
    /// clamped to at least 1.
    pub fn new(capacity: usize, sample_every: u64) -> SpanRecorder {
        let capacity = capacity.max(1);
        SpanRecorder {
            shared: Arc::new(Shared {
                epoch: Instant::now(),
                capacity,
                sample_every: sample_every.max(1),
                seen: AtomicU64::new(0),
                next_req: AtomicU64::new(0),
                next_batch: AtomicU64::new(0),
                ring: Mutex::new(Ring {
                    buf: Vec::with_capacity(capacity),
                    next: 0,
                    dropped: 0,
                }),
                shard_names: Mutex::new(Vec::new()),
            }),
        }
    }

    /// Record the per-shard backend keys (export track names).
    pub fn configure_shards(&self, names: &[String]) {
        *self.shared.shard_names.lock().unwrap() = names.to_vec();
    }

    pub fn shard_names(&self) -> Vec<String> {
        self.shared.shard_names.lock().unwrap().clone()
    }

    /// Nanoseconds since the recorder's epoch.
    pub fn now_ns(&self) -> u64 {
        self.shared.epoch.elapsed().as_nanos() as u64
    }

    pub fn capacity(&self) -> usize {
        self.shared.capacity
    }

    pub fn sample_every(&self) -> u64 {
        self.shared.sample_every
    }

    /// Admission gate: counts the request and, when it lands on the
    /// sampling grid, mints a request id and stamps [`Phase::Admitted`].
    /// `None` means "not sampled — stamp nothing downstream"; the whole
    /// cost for such a request is this one atomic increment.
    pub fn admit(&self, class_m: usize) -> Option<u64> {
        let seen = self.shared.seen.fetch_add(1, Ordering::Relaxed);
        if seen % self.shared.sample_every != 0 {
            return None;
        }
        let req = self.shared.next_req.fetch_add(1, Ordering::Relaxed) + 1;
        self.request(Phase::Admitted, req, class_m);
        Some(req)
    }

    /// Stamp a request-scope instant.
    pub fn request(&self, phase: Phase, req: u64, class_m: usize) {
        self.push(SpanEvent {
            at_ns: self.now_ns(),
            phase,
            req: Some(req),
            batch: None,
            shard: None,
            dur_ns: 0,
            n: 0,
            class_m: class_m as u32,
            stolen: false,
        });
    }

    /// Stamp a request-scope event linked to a batch (and optionally the
    /// shard it ran on).
    pub fn request_in_batch(
        &self,
        phase: Phase,
        req: u64,
        batch: u64,
        shard: Option<usize>,
        class_m: usize,
    ) {
        self.push(SpanEvent {
            at_ns: self.now_ns(),
            phase,
            req: Some(req),
            batch: Some(batch),
            shard: shard.map(|s| s as u32),
            dur_ns: 0,
            n: 0,
            class_m: class_m as u32,
            stolen: false,
        });
    }

    /// Mint a batch id (1-based).
    pub fn next_batch_id(&self) -> u64 {
        self.shared.next_batch.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Stamp a batch-scope instant.
    pub fn batch(
        &self,
        phase: Phase,
        batch: u64,
        shard: usize,
        n: usize,
        class_m: usize,
        stolen: bool,
    ) {
        self.batch_timed(phase, batch, shard, n, class_m, stolen, self.now_ns(), 0);
    }

    /// Stamp a batch-scope span starting at `start_ns` (recorder
    /// timeline) lasting `dur_ns` (0 = instant).
    #[allow(clippy::too_many_arguments)]
    pub fn batch_timed(
        &self,
        phase: Phase,
        batch: u64,
        shard: usize,
        n: usize,
        class_m: usize,
        stolen: bool,
        start_ns: u64,
        dur_ns: u64,
    ) {
        self.push(SpanEvent {
            at_ns: start_ns,
            phase,
            req: None,
            batch: Some(batch),
            shard: Some(shard as u32),
            dur_ns,
            n: n as u32,
            class_m: class_m as u32,
            stolen,
        });
    }

    fn push(&self, ev: SpanEvent) {
        let mut ring = self.shared.ring.lock().unwrap();
        if ring.buf.len() < self.shared.capacity {
            ring.buf.push(ev);
        } else {
            let next = ring.next;
            ring.buf[next] = ev;
            ring.next = (next + 1) % self.shared.capacity;
            ring.dropped += 1;
        }
    }

    /// Events in chronological (recording) order. When the ring has
    /// wrapped, the oldest surviving event comes first.
    pub fn events(&self) -> Vec<SpanEvent> {
        let ring = self.shared.ring.lock().unwrap();
        if ring.buf.len() < self.shared.capacity {
            ring.buf.clone()
        } else {
            let mut out = Vec::with_capacity(ring.buf.len());
            out.extend_from_slice(&ring.buf[ring.next..]);
            out.extend_from_slice(&ring.buf[..ring.next]);
            out
        }
    }

    /// Events currently held.
    pub fn len(&self) -> usize {
        self.shared.ring.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events overwritten because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.shared.ring.lock().unwrap().dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_admits_every_nth_request() {
        let rec = SpanRecorder::new(64, 3);
        let sampled: Vec<Option<u64>> = (0..9).map(|_| rec.admit(16)).collect();
        // Requests 0, 3, 6 land on the grid and get ids 1, 2, 3.
        assert_eq!(
            sampled,
            vec![
                Some(1),
                None,
                None,
                Some(2),
                None,
                None,
                Some(3),
                None,
                None
            ]
        );
        // Each sampled admit stamped exactly one Admitted event.
        let events = rec.events();
        assert_eq!(events.len(), 3);
        assert!(events.iter().all(|e| e.phase == Phase::Admitted));
        assert_eq!(events[0].req, Some(1));
        assert_eq!(events[2].req, Some(3));
    }

    #[test]
    fn sample_every_one_traces_everything() {
        let rec = SpanRecorder::new(8, 1);
        for i in 0..4u64 {
            assert_eq!(rec.admit(16), Some(i + 1));
        }
        assert_eq!(rec.len(), 4);
        assert_eq!(rec.dropped(), 0);
    }

    #[test]
    fn zero_knobs_are_clamped() {
        let rec = SpanRecorder::new(0, 0);
        assert_eq!(rec.capacity(), 1);
        assert_eq!(rec.sample_every(), 1);
        assert_eq!(rec.admit(16), Some(1));
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let rec = SpanRecorder::new(4, 1);
        for _ in 0..6 {
            rec.admit(16);
        }
        assert_eq!(rec.len(), 4);
        assert_eq!(rec.dropped(), 2);
        // Oldest-first unwind: ids 3, 4, 5, 6 survive (1 and 2 dropped).
        let ids: Vec<Option<u64>> = rec.events().iter().map(|e| e.req).collect();
        assert_eq!(ids, vec![Some(3), Some(4), Some(5), Some(6)]);
        // Timestamps come out non-decreasing.
        let ts: Vec<u64> = rec.events().iter().map(|e| e.at_ns).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn batch_events_carry_shard_size_and_steal_flag() {
        let rec = SpanRecorder::new(16, 1);
        let b = rec.next_batch_id();
        assert_eq!(b, 1);
        let t0 = rec.now_ns();
        rec.batch_timed(Phase::Staged, b, 0, 4, 16, false, t0, 1_000);
        rec.batch(Phase::Stolen, b, 0, 4, 16, true);
        rec.batch_timed(Phase::Executed, b, 1, 4, 16, true, rec.now_ns(), 2_000);
        let events = rec.events();
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].phase, Phase::Staged);
        assert_eq!(events[0].dur_ns, 1_000);
        assert_eq!(events[0].shard, Some(0));
        assert_eq!(events[0].n, 4);
        assert!(events[1].stolen);
        assert_eq!(events[2].shard, Some(1));
        assert!(events.iter().all(|e| e.req.is_none() && e.batch == Some(1)));
    }

    #[test]
    fn request_in_batch_links_both_ids() {
        let rec = SpanRecorder::new(16, 1);
        let req = rec.admit(64).unwrap();
        let b = rec.next_batch_id();
        rec.request_in_batch(Phase::BatchClosed, req, b, None, 64);
        rec.request_in_batch(Phase::Replied, req, b, Some(2), 64);
        let events = rec.events();
        assert_eq!(events[1].req, Some(req));
        assert_eq!(events[1].batch, Some(b));
        assert_eq!(events[2].shard, Some(2));
    }

    #[test]
    fn recorder_clones_share_the_ring() {
        let rec = SpanRecorder::new(16, 1);
        let clone = rec.clone();
        rec.admit(16);
        clone.admit(16);
        assert_eq!(rec.len(), 2);
        assert_eq!(clone.len(), 2);
        clone.configure_shards(&["cpu".to_string()]);
        assert_eq!(rec.shard_names(), vec!["cpu".to_string()]);
    }

    #[test]
    fn phase_names_are_distinct() {
        let mut names: Vec<&str> = Phase::ALL.iter().map(|p| p.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Phase::ALL.len());
    }
}

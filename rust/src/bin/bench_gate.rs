//! CI perf-regression gate: diff a fresh `BENCH_pipeline.json` against the
//! committed `BENCH_baseline.json` and fail on a throughput regression.
//!
//! ```sh
//! bench_gate <baseline.json> <fresh.json> [--tolerance 0.15]
//! bench_gate --refresh <baseline.json> <fresh.json>
//! ```
//!
//! Records are matched by `bench` name (plus the `shards` count and
//! pipeline `depth` when present). A record regresses when its fresh
//! `throughput_lps` drops more than `tolerance` below the baseline's; any
//! regression — or a baseline record missing from the fresh run — exits
//! non-zero, which is what fails the workflow. Baseline records with
//! `throughput_lps <= 0` are *bootstrap* rows: they pin the expected
//! record set without pinning a number yet.
//!
//! `--refresh` arms the gate: it rewrites the baseline file from a fresh
//! run's records (dropping engine-path records, which stay out of the
//! baseline until real PJRT bindings run in CI), preserving the documented
//! header comment. Run it on the reference runner after a representative
//! `cargo bench --bench solver_micro` followed by `cargo bench --bench
//! loadgen` (solver_micro rewrites `BENCH_pipeline.json`; loadgen merges
//! its latency-under-load records into it).
//!
//! The parser is a minimal field scanner for the flat `[{...}, ...]`
//! array `solver_micro` emits — the offline vendor set has no serde, and
//! the gate must not drag a JSON crate into the build.

use std::process::ExitCode;

/// Default relative throughput drop that fails the gate.
const DEFAULT_TOLERANCE: f64 = 0.15;

/// The `_comment` object `--refresh` writes at the head of the baseline.
const BASELINE_HEADER: &str = "Committed perf baseline for the CI bench-regression gate \
(bench_gate). Rows with throughput_lps <= 0 are bootstrap rows: they pin the record set the \
fresh run must produce, without pinning a number yet. The simd_micro_* rows track the 8-lane \
f64 kernel and the simd_f32_micro_* rows its 16-lane wire-precision (f32) twin; once armed, \
the f32 rows should sit at or above the f64 rows at equal threads. Refresh on the reference \
runner with: \
BATCH_LP2D_BENCH_FAST=1 cargo bench --bench solver_micro && BATCH_LP2D_BENCH_FAST=1 cargo \
bench --bench loadgen && BATCH_LP2D_BENCH_FAST=1 cargo bench --bench calibration && \
BATCH_LP2D_BENCH_FAST=1 cargo bench --bench reuse && cargo \
run --release --bin bench_gate -- --refresh BENCH_baseline.json BENCH_pipeline.json \
(solver_micro rewrites BENCH_pipeline.json; loadgen, calibration, and reuse merge their \
loadgen_*, tune_*, and sim_steps_*/cache_* records into it — run them in that order or \
those rows never reach the baseline). Engine-path records (pipeline_engine_*, pipeline_shard_engine) are excluded \
automatically until the real PJRT bindings replace the offline xla stub in CI.";

/// One comparable bench record: match key + throughput, plus the fields
/// the key derives from (so `--refresh` can re-emit the record).
#[derive(Clone, Debug, PartialEq)]
struct Record {
    key: String,
    bench: String,
    shards: Option<u64>,
    depth: Option<u64>,
    throughput_lps: f64,
}

use batch_lp2d::util::flatjson::{extract_num, extract_str, split_flat_objects};

/// Parse every `{...}` object carrying a `bench` + `throughput_lps` pair.
/// Object splitting and field extraction are shared with the loadgen
/// merge path and the tune profile loader (`batch_lp2d::util::flatjson`)
/// so the readers of `BENCH_pipeline.json` cannot drift.
fn parse_records(text: &str) -> Vec<Record> {
    let mut out = Vec::new();
    for obj in split_flat_objects(text) {
        let obj = obj.as_str();
        let (Some(bench), Some(lps)) =
            (extract_str(obj, "bench"), extract_num(obj, "throughput_lps"))
        else {
            continue;
        };
        let shards = extract_num(obj, "shards").map(|s| s as u64);
        let depth = extract_num(obj, "depth").map(|d| d as u64);
        let mut key = bench.clone();
        if let Some(s) = shards {
            key.push_str(&format!("/shards={s}"));
        }
        if let Some(d) = depth {
            key.push_str(&format!("/depth={d}"));
        }
        out.push(Record { key, bench, shards, depth, throughput_lps: lps });
    }
    out
}

/// True when not a single baseline record pins a number — the gate can
/// only check record-set presence, not performance. CI output must say so
/// loudly instead of printing an ordinary pass.
fn baseline_unarmed(baseline: &[Record]) -> bool {
    baseline.iter().all(|b| b.throughput_lps <= 0.0)
}

/// The loud banner printed when the committed baseline is still all
/// bootstrap rows, with the exact refresh command.
fn unarmed_warning(baseline_path: &str) -> String {
    format!(
        "##############################################################\n\
         # BASELINE UNARMED: every record in {baseline_path} is a\n\
         # bootstrap row (throughput_lps <= 0). The bench gate checked\n\
         # only that the record set matches — NO throughput regression\n\
         # was (or could be) detected, and the simd_f32_micro_* >= \n\
         # simd_micro_* lane-family ordering was not checked either.\n\
         # Arm it on the reference runner\n\
         # (in this order — solver_micro rewrites the snapshot; loadgen,\n\
         # calibration, and reuse merge into it):\n\
         #   BATCH_LP2D_BENCH_FAST=1 cargo bench --bench solver_micro\n\
         #   BATCH_LP2D_BENCH_FAST=1 cargo bench --bench loadgen\n\
         #   BATCH_LP2D_BENCH_FAST=1 cargo bench --bench calibration\n\
         #   BATCH_LP2D_BENCH_FAST=1 cargo bench --bench reuse\n\
         #   cargo run --release --bin bench_gate -- --refresh \\\n\
         #     BENCH_baseline.json BENCH_pipeline.json\n\
         # While you are at it, refresh the dispatch calibration too:\n\
         #   cargo run --release -- tune --backends batch-cpu:2,cpu \\\n\
         #     --out TUNE_profile.json\n\
         ##############################################################"
    )
}

/// Compare fresh against baseline; Ok carries the report lines, Err the
/// report lines plus the failure summary.
fn compare(
    baseline: &[Record],
    fresh: &[Record],
    tolerance: f64,
) -> Result<Vec<String>, Vec<String>> {
    let mut lines = Vec::new();
    let mut failures = 0usize;
    for b in baseline {
        let Some(f) = fresh.iter().find(|f| f.key == b.key) else {
            failures += 1;
            lines.push(format!("FAIL  {:<40} missing from fresh run", b.key));
            continue;
        };
        if b.throughput_lps <= 0.0 {
            lines.push(format!(
                "boot  {:<40} baseline unset, fresh {:.1} LPs/s (refresh baseline)",
                b.key, f.throughput_lps
            ));
            continue;
        }
        let ratio = f.throughput_lps / b.throughput_lps;
        let verdict = if ratio < 1.0 - tolerance {
            failures += 1;
            "FAIL"
        } else {
            "ok  "
        };
        lines.push(format!(
            "{verdict}  {:<40} base {:.1}  fresh {:.1}  ({:+.1}%)",
            b.key,
            b.throughput_lps,
            f.throughput_lps,
            (ratio - 1.0) * 100.0
        ));
    }
    for f in fresh {
        if !baseline.iter().any(|b| b.key == f.key) {
            lines.push(format!(
                "new   {:<40} fresh {:.1} LPs/s (no baseline yet)",
                f.key, f.throughput_lps
            ));
        }
    }
    if failures > 0 {
        lines.push(format!(
            "bench gate: {failures} regression(s) beyond {:.0}% tolerance",
            tolerance * 100.0
        ));
        Err(lines)
    } else {
        Ok(lines)
    }
}

/// Records `--refresh` keeps: the engine-path benches stay out of the
/// committed baseline until a CI runner actually executes them.
fn refreshable(r: &Record) -> bool {
    !r.bench.contains("engine")
}

/// Render a baseline file from fresh records: the documented header
/// comment, then one flat object per record with exactly the fields the
/// gate keys on.
fn render_baseline(records: &[Record]) -> String {
    let mut out = String::from("[\n  {\n    \"_comment\": \"");
    out.push_str(BASELINE_HEADER);
    out.push_str("\"\n  }");
    for r in records {
        out.push_str(",\n  {\n");
        out.push_str(&format!("    \"bench\": \"{}\",\n", r.bench));
        if let Some(s) = r.shards {
            out.push_str(&format!("    \"shards\": {s},\n"));
        }
        if let Some(d) = r.depth {
            out.push_str(&format!("    \"depth\": {d},\n"));
        }
        out.push_str(&format!("    \"throughput_lps\": {:.1}\n  }}", r.throughput_lps));
    }
    out.push_str("\n]\n");
    out
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut paths: Vec<&String> = Vec::new();
    let mut refresh = false;
    let mut tolerance = std::env::var("BENCH_GATE_TOLERANCE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_TOLERANCE);
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--tolerance" {
            i += 1;
            tolerance = args.get(i).and_then(|v| v.parse().ok()).unwrap_or(tolerance);
        } else if args[i] == "--refresh" {
            refresh = true;
        } else {
            paths.push(&args[i]);
        }
        i += 1;
    }
    if paths.len() != 2 {
        eprintln!(
            "usage: bench_gate [--refresh] <baseline.json> <fresh.json> [--tolerance 0.15]"
        );
        return ExitCode::from(2);
    }
    let read = |path: &str| match std::fs::read_to_string(path) {
        Ok(text) => Some(text),
        Err(e) => {
            eprintln!("bench_gate: cannot read {path}: {e}");
            None
        }
    };

    if refresh {
        let Some(fresh_text) = read(paths[1]) else {
            return ExitCode::from(2);
        };
        let records: Vec<Record> =
            parse_records(&fresh_text).into_iter().filter(refreshable).collect();
        if records.is_empty() {
            eprintln!("bench_gate: no refreshable records in {}", paths[1]);
            return ExitCode::from(2);
        }
        let rendered = render_baseline(&records);
        if let Err(e) = std::fs::write(paths[0], rendered) {
            eprintln!("bench_gate: cannot write {}: {e}", paths[0]);
            return ExitCode::from(2);
        }
        println!(
            "bench gate: refreshed {} with {} record(s) from {}",
            paths[0],
            records.len(),
            paths[1]
        );
        return ExitCode::SUCCESS;
    }

    let (Some(base_text), Some(fresh_text)) = (read(paths[0]), read(paths[1])) else {
        return ExitCode::from(2);
    };
    let baseline = parse_records(&base_text);
    let fresh = parse_records(&fresh_text);
    if baseline.is_empty() {
        eprintln!("bench_gate: no comparable records in {}", paths[0]);
        return ExitCode::from(2);
    }
    println!(
        "bench gate: {} baseline record(s), {} fresh, tolerance {:.0}%",
        baseline.len(),
        fresh.len(),
        tolerance * 100.0
    );
    match compare(&baseline, &fresh, tolerance) {
        Ok(lines) => {
            for l in lines {
                println!("{l}");
            }
            // A bootstrap-only baseline must never read as a quiet pass:
            // the gate checked nothing but record presence.
            if baseline_unarmed(&baseline) {
                println!("{}", unarmed_warning(paths[0]));
                println!("bench gate: OK (record set only — BASELINE UNARMED)");
            } else {
                println!("bench gate: OK");
            }
            ExitCode::SUCCESS
        }
        Err(lines) => {
            for l in lines {
                println!("{l}");
            }
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"[
  {
    "bench": "pipeline_cpu",
    "chunks": 8,
    "throughput_lps": 1000.5
  },
  {
    "bench": "pipeline_shard_cpu",
    "shards": 2,
    "throughput_lps": 1800.0
  },
  {
    "bench": "pipeline_depth_cpu",
    "depth": 3,
    "throughput_lps": 1900.0
  },
  {
    "bench": "pipeline_shard_engine",
    "shards": 2,
    "throughput_lps": 9000.0
  }
]"#;

    fn rec(key: &str, lps: f64) -> Record {
        let (bench, rest) = match key.split_once('/') {
            Some((b, r)) => (b.to_string(), Some(r)),
            None => (key.to_string(), None),
        };
        let field = |name: &str| {
            rest.and_then(|r| {
                r.split('/')
                    .find_map(|p| p.strip_prefix(&format!("{name}=")))
                    .and_then(|v| v.parse().ok())
            })
        };
        Record {
            key: key.to_string(),
            bench,
            shards: field("shards"),
            depth: field("depth"),
            throughput_lps: lps,
        }
    }

    #[test]
    fn parses_keys_and_throughput() {
        let records = parse_records(SAMPLE);
        assert_eq!(
            records,
            vec![
                rec("pipeline_cpu", 1000.5),
                rec("pipeline_shard_cpu/shards=2", 1800.0),
                rec("pipeline_depth_cpu/depth=3", 1900.0),
                rec("pipeline_shard_engine/shards=2", 9000.0),
            ]
        );
    }

    #[test]
    fn within_tolerance_passes() {
        let base = vec![rec("a", 100.0)];
        let fresh = vec![rec("a", 90.0)]; // -10% with 15% tolerance
        assert!(compare(&base, &fresh, 0.15).is_ok());
    }

    #[test]
    fn regression_beyond_tolerance_fails() {
        let base = vec![rec("a", 100.0), rec("b", 50.0)];
        let fresh = vec![rec("a", 80.0), rec("b", 50.0)]; // a: -20%
        let lines = compare(&base, &fresh, 0.15).unwrap_err();
        assert!(lines.iter().any(|l| l.starts_with("FAIL") && l.contains('a')));
    }

    #[test]
    fn missing_fresh_record_fails() {
        let base = vec![rec("a", 100.0)];
        assert!(compare(&base, &[], 0.15).is_err());
    }

    #[test]
    fn bootstrap_baseline_passes_and_improvements_pass() {
        let base = vec![rec("a", 0.0), rec("b", 100.0)];
        let fresh = vec![rec("a", 5000.0), rec("b", 400.0), rec("c", 1.0)];
        let lines = compare(&base, &fresh, 0.15).unwrap();
        assert!(lines.iter().any(|l| l.starts_with("boot")));
        assert!(lines.iter().any(|l| l.starts_with("new")));
    }

    #[test]
    fn unarmed_detection_and_warning_text() {
        // All-bootstrap baseline: unarmed, and the warning names the file,
        // the condition, and the exact refresh command.
        let boot = vec![rec("a", 0.0), rec("b", -1.0)];
        assert!(baseline_unarmed(&boot));
        // One armed record is enough to count as armed.
        let mixed = vec![rec("a", 0.0), rec("b", 100.0)];
        assert!(!baseline_unarmed(&mixed));
        assert!(baseline_unarmed(&[]));
        let w = unarmed_warning("BENCH_baseline.json");
        assert!(w.contains("BASELINE UNARMED"));
        assert!(w.contains("BENCH_baseline.json"));
        assert!(w.contains("--refresh"));
        assert!(w.contains("bench_gate"));
    }

    #[test]
    fn refresh_renders_a_reparseable_baseline_without_engine_rows() {
        let records: Vec<Record> =
            parse_records(SAMPLE).into_iter().filter(refreshable).collect();
        // The engine-path record is dropped.
        assert_eq!(records.len(), 3);
        assert!(records.iter().all(|r| !r.bench.contains("engine")));
        let rendered = render_baseline(&records);
        // The header comment survives as a non-record object; the records
        // round-trip key-for-key with their throughputs.
        assert!(rendered.contains("_comment"));
        let reparsed = parse_records(&rendered);
        assert_eq!(reparsed, records);
    }
}

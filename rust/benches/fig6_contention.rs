//! Figure 6: reduction mechanism performance vs contention (2..512).
//! Pure-CPU bench (no artifacts needed).  `cargo bench --bench fig6_contention`

use batch_lp2d::bench::contention::{run, Method, Workload, CONTENTIONS};
use batch_lp2d::bench::{bench, BenchOpts};
use batch_lp2d::util::{Rng, Table};

fn main() {
    let opts = BenchOpts::from_env();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let n = 1 << 22; // 4M elements, matching a large-batch reduction load
    let mut table = Table::new(&[
        "contention",
        "global_atomic_ms",
        "sharded_atomic_ms",
        "segmented_reduce_ms",
    ]);

    for &c in CONTENTIONS {
        let mut rng = Rng::new(2019 ^ c as u64);
        let w = Workload::new(&mut rng, n, c);
        let mut row = vec![c.to_string()];
        for method in Method::all() {
            let r = bench(&format!("{}/c{c}", method.label()), opts, || {
                std::hint::black_box(run(method, &w, threads));
            });
            row.push(format!("{:.3}", r.mean_ms()));
        }
        eprintln!("  {}", row.join("\t"));
        table.push_row(row);
    }
    println!("\n## Figure 6 (reduction vs contention, {n} elems, {threads} threads)\n");
    print!("{}", table.to_markdown());
}

//! Calibration-accuracy bench: profile the backend mix, validate the
//! fitted cost models against fresh measurements, and emit the
//! predicted-vs-measured table.
//!
//! ```sh
//! cargo bench --bench calibration -- \
//!     [--backends LIST] [--runs N] [--max-batch B] [--seed S]
//! ```
//!
//! Defaults run the portable CPU-only heterogeneous mix (no artifacts
//! needed). Results go three places: stdout (markdown table),
//! `TUNE_table.md` (the CI artifact), and `BENCH_pipeline.json` (the
//! `tune_*` records merged next to the solver_micro and loadgen rows for
//! the perf gate). `BATCH_LP2D_BENCH_FAST=1` shrinks the grid for CI.

use batch_lp2d::bench::calibration::{json_records, run, table};
use batch_lp2d::bench::loadgen::merge_prefixed_records;
use batch_lp2d::coordinator::BackendSpec;
use batch_lp2d::runtime::{default_artifact_dir, Variant};
use batch_lp2d::tune::ProfilerOpts;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = std::env::var_os("BATCH_LP2D_BENCH_FAST").is_some();
    let mut specs = vec![BackendSpec::BatchCpu { threads: 2 }, BackendSpec::Cpu];
    let mut opts = ProfilerOpts {
        runs: if fast { 1 } else { 3 },
        max_batch: if fast { 256 } else { 512 },
        ..ProfilerOpts::default()
    };

    let mut i = 0usize;
    while i < args.len() {
        let flag = args[i].clone();
        let mut value = || -> Option<String> {
            i += 1;
            args.get(i).cloned()
        };
        match flag.as_str() {
            "--backends" => {
                specs = BackendSpec::parse_list(&value().unwrap_or_default())?;
            }
            "--runs" => {
                opts.runs = value().and_then(|v| v.parse().ok()).unwrap_or(opts.runs);
            }
            "--max-batch" => {
                opts.max_batch =
                    value().and_then(|v| v.parse().ok()).unwrap_or(opts.max_batch);
            }
            "--seed" => {
                opts.seed = value().and_then(|v| v.parse().ok()).unwrap_or(opts.seed);
            }
            // cargo bench passes through its own flags; ignore the rest.
            _ => {}
        }
        i += 1;
    }

    println!(
        "## calibration accuracy: {} backend spec(s), {} runs/point, batches <= {}",
        specs.len(),
        opts.runs,
        opts.max_batch
    );
    let report = run(&specs, &default_artifact_dir(), Variant::Rgb, &opts)?;
    for b in &report.profile.backends {
        for c in &b.classes {
            println!(
                "fit {}/m{}: setup {:.0} ns + {:.1} ns/problem (calibrated weight {:.2})",
                b.backend, c.class_m, c.setup_ns, c.per_problem_ns, c.calibrated_weight()
            );
        }
    }
    let t = table(&report.rows);
    println!("\n{}", t.to_markdown());
    println!(
        "validation: {} cells  {:.0} LPs/s  mean |rel err| {:.1}%",
        report.rows.len(),
        report.throughput_lps,
        100.0 * report.mean_abs_err
    );

    std::fs::write("TUNE_table.md", t.to_markdown())
        .map_err(|e| anyhow::anyhow!("cannot write TUNE_table.md: {e}"))?;
    let records = json_records(&report);
    merge_prefixed_records(std::path::Path::new("BENCH_pipeline.json"), &records, "tune_")?;
    println!(
        "wrote TUNE_table.md and merged {} record(s) into BENCH_pipeline.json",
        records.len()
    );
    Ok(())
}

//! Figures 3a-3c: batch-solve time vs LP size at fixed batch counts
//! (128 / 2048 / 4096-scaled), all series.  `cargo bench --bench fig3_size_sweep`

use batch_lp2d::bench::figures::{self, FigureCtx};
use batch_lp2d::runtime::{default_artifact_dir, Engine};

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(default_artifact_dir())?;
    let ctx = FigureCtx::new(&engine);
    for (name, batch) in [("3a", 128usize), ("3b", 2048), ("3c", 4096)] {
        eprintln!("figure {name}: batch {batch}");
        let t = figures::fig3(&ctx, batch, figures::SIZES);
        println!("\n## Figure {name} (time_ms vs lp_size, batch {batch})\n");
        print!("{}", t.to_markdown());
    }
    Ok(())
}

//! Cross-request reuse bench: sim steps/second cold vs warm-started, and
//! cache hit-rate sweeps over coherence levels — the headline numbers for
//! the content-addressed result cache + warm-started Seidel layer.
//!
//! ```sh
//! cargo bench --bench reuse -- \
//!     [--agents N] [--steps N] [--threads N] [--requests N] \
//!     [--capacity N] [--coherence 0.0,0.5,0.9]
//! ```
//!
//! Steps the clearance crowd with warm-start off then on (the measured
//! improvement line the acceptance gate reads), then serves duplicate-rich
//! request streams at each coherence level through a cached service and a
//! cache-disabled reference, asserting the replies are **bit-identical**
//! (the run fails otherwise — reuse must never change result bits) and
//! that coherent levels (>= 0.5) actually hit. Results go to stdout,
//! `CACHE_table.md`, and `BENCH_pipeline.json` (merged as `sim_steps_*`
//! and `cache_*` records for the perf gate). `BATCH_LP2D_BENCH_FAST=1`
//! shrinks the step/request counts for CI; the coherence levels stay
//! fixed so the gate's baseline rows are always produced.

use batch_lp2d::bench::loadgen::merge_prefixed_records;
use batch_lp2d::bench::reuse::{
    cache_json_record, render_markdown, run_cache_level, run_sim, sim_json_record, ReuseOpts,
};
use batch_lp2d::runtime::default_artifact_dir;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = std::env::var_os("BATCH_LP2D_BENCH_FAST").is_some();
    let mut opts = if fast {
        ReuseOpts { agents: 64, steps: 40, requests: 1_200, ..ReuseOpts::default() }
    } else {
        ReuseOpts::default()
    };

    let mut i = 0usize;
    while i < args.len() {
        let flag = args[i].clone();
        let mut value = || -> Option<String> {
            i += 1;
            args.get(i).cloned()
        };
        match flag.as_str() {
            "--agents" => {
                opts.agents = value().and_then(|v| v.parse().ok()).unwrap_or(opts.agents);
            }
            "--steps" => {
                opts.steps = value().and_then(|v| v.parse().ok()).unwrap_or(opts.steps);
            }
            "--threads" => {
                opts.threads = value().and_then(|v| v.parse().ok()).unwrap_or(opts.threads);
            }
            "--requests" => {
                opts.requests = value().and_then(|v| v.parse().ok()).unwrap_or(opts.requests);
            }
            "--capacity" => {
                opts.cache_capacity =
                    value().and_then(|v| v.parse().ok()).unwrap_or(opts.cache_capacity);
            }
            "--coherence" => {
                if let Some(list) = value() {
                    let levels: Result<Vec<f64>, _> =
                        list.split(',').map(|s| s.trim().parse::<f64>()).collect();
                    let levels = levels.map_err(|e| anyhow::anyhow!("--coherence: {e}"))?;
                    anyhow::ensure!(
                        levels.iter().all(|c| (0.0..=1.0).contains(c)),
                        "--coherence levels must be in [0, 1]"
                    );
                    opts.coherence = levels;
                }
            }
            // cargo bench passes through its own flags (e.g. --bench);
            // ignore anything unrecognized rather than failing the run.
            _ => {}
        }
        i += 1;
    }

    println!(
        "## reuse: {} agents x {} steps (cold vs warm), {} requests per coherence level {:?}",
        opts.agents, opts.steps, opts.requests, opts.coherence
    );

    let mut sims = Vec::new();
    for warm in [false, true] {
        let r = run_sim(&opts, warm)?;
        println!(
            "sim {:<5} {:>7.1} steps/s  {:>8.0} LPs/s  warm_hits {}",
            r.mode, r.steps_per_s, r.throughput_lps, r.warm_hits
        );
        sims.push(r);
    }

    let dir = default_artifact_dir();
    let mut sweeps = Vec::new();
    for &c in &opts.coherence {
        let r = run_cache_level(&dir, c, &opts)?;
        println!(
            "cache c={:.2} {:>6} ok  hits {:>6}  misses {:>6}  hit-rate {:.3}  \
             {:>7.0} LPs/s  bit-identical {}",
            r.coherence, r.completed, r.hits, r.misses, r.hit_rate, r.throughput_lps,
            r.bit_identical
        );
        anyhow::ensure!(
            r.bit_identical,
            "coherence {:.2}: cached replies differ from the cache-disabled run",
            r.coherence
        );
        anyhow::ensure!(
            r.coherence < 0.5 || r.hits > 0,
            "coherence {:.2}: expected a nonzero cache hit rate, got {} hits",
            r.coherence,
            r.hits
        );
        sweeps.push(r);
    }

    let md = render_markdown(&sims, &sweeps);
    println!("\n{md}");
    std::fs::write("CACHE_table.md", &md)
        .map_err(|e| anyhow::anyhow!("cannot write CACHE_table.md: {e}"))?;

    let sim_records: Vec<String> = sims.iter().map(sim_json_record).collect();
    let cache_records: Vec<String> = sweeps.iter().map(cache_json_record).collect();
    let path = std::path::Path::new("BENCH_pipeline.json");
    merge_prefixed_records(path, &sim_records, "sim_steps_")?;
    merge_prefixed_records(path, &cache_records, "cache_")?;
    println!(
        "wrote CACHE_table.md and merged {} record(s) into BENCH_pipeline.json",
        sim_records.len() + cache_records.len()
    );
    Ok(())
}

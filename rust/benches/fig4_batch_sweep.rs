//! Figures 4a-4b: batch-solve time vs batch count at fixed LP sizes
//! (64 and 256-scaled-from-8192).  `cargo bench --bench fig4_batch_sweep`

use batch_lp2d::bench::figures::{self, FigureCtx};
use batch_lp2d::runtime::{default_artifact_dir, Engine};

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(default_artifact_dir())?;
    let ctx = FigureCtx::new(&engine);
    for (name, m) in [("4a", 64usize), ("4b", 256)] {
        eprintln!("figure {name}: m {m}");
        let t = figures::fig4(&ctx, m, figures::BATCHES);
        println!("\n## Figure {name} (time_ms vs batch, lp_size {m})\n");
        print!("{}", t.to_markdown());
    }
    Ok(())
}

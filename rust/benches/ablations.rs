//! Ablation bench: randomization order, padding waste, replicated-vs-
//! independent batches, serving batch window.  `cargo bench --bench ablations`

use batch_lp2d::bench::ablations;
use batch_lp2d::bench::BenchOpts;
use batch_lp2d::runtime::{default_artifact_dir, Engine};

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env();
    let dir = default_artifact_dir();
    let engine = Engine::new(&dir)?;

    println!("\n## Ablation: constraint-order randomization (Seidel, CPU)\n");
    print!("{}", ablations::randomization_table(&[64, 256, 1024, 4096], opts).to_markdown());

    println!("\n## Ablation: bucket padding waste (batch 1024, true m 16)\n");
    print!(
        "{}",
        ablations::padding_table(&engine, 1024, 16, &[16, 32, 64, 128, 256], opts)?.to_markdown()
    );

    println!("\n## Ablation: replicated vs independent batches (batch 1024)\n");
    print!("{}", ablations::batch_mix_table(&engine, 1024, &[16, 64, 256], opts)?.to_markdown());

    println!("\n## Ablation: serving batch window (2000 x m<=64 requests)\n");
    print!("{}", ablations::batch_window_table(&dir, &[1, 2, 5, 10, 20], 2000, 48)?.to_markdown());
    Ok(())
}

//! Figure 5: fraction of RGB wall time spent on memory management
//! (pack + literal staging + unpack) over a (batch x size) grid.
//! `cargo bench --bench fig5_memory_split`

use batch_lp2d::bench::figures::{self, FigureCtx};
use batch_lp2d::runtime::{default_artifact_dir, Engine};

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(default_artifact_dir())?;
    let ctx = FigureCtx::new(&engine);
    let t = figures::fig5(&ctx, &[128, 512, 2048, 4096], &[16, 32, 64, 128, 256])?;
    println!("\n## Figure 5 (memory-management fraction)\n");
    print!("{}", t.to_markdown());

    // Companion: how much of that memory time the double-buffered stream
    // hides behind execution.
    let t = figures::fig5_pipeline(&ctx, 512, 64, &[2, 4, 8, 16])?;
    println!("\n## Figure 5 companion (pipelined solve_stream overlap)\n");
    print!("{}", t.to_markdown());
    Ok(())
}

//! Latency-under-load bench: drive the serving layer with the
//! scenario-diverse open-loop traffic models and emit the latency
//! percentile table (p50/p95/p99 end-to-end, queue-wait vs execute split,
//! shed counts) — the serving counterpart of `solver_micro`'s closed-loop
//! throughput sweeps.
//!
//! ```sh
//! cargo bench --bench loadgen -- \
//!     [--scenario poisson,bursty,...,trace:PATH | all] [--requests N] \
//!     [--rate R] [--shards N] [--backends LIST] [--depth D] \
//!     [--policy fixed|adaptive] [--max-queue N] [--slo-ms MS] \
//!     [--bulk-slo-ms MS] [--replay-speed X] [--gate-p99-ms MS] [--gate-shed N] \
//!     [--metrics-out METRICS_loadgen.prom]
//! ```
//!
//! Defaults run every scenario on a portable CPU-only heterogeneous shard
//! mix (no artifacts needed). `--scenario trace:PATH` replays a captured
//! trace fixture (see `serve --capture`) deterministically;
//! `--replay-speed X` time-compresses the replay by X (same request
//! stream, 1/X the wall clock — a day-long capture in minutes). Results go
//! three places: stdout (markdown table), `LOADGEN_table.md` (the CI
//! artifact), and `BENCH_pipeline.json` (merged alongside the solver_micro
//! records for the perf gate). `--gate-p99-ms` / `--gate-shed` turn the
//! run into a pass/fail gate: any scenario whose e2e p99 or shed count
//! exceeds the bound fails the bench with a nonzero exit (the CI trace leg
//! gates replayed fixtures this way). `--metrics-out PATH` writes the
//! last scenario's final metrics snapshot as a Prometheus text exposition
//! (the same format `serve --metrics-out` emits).
//! `BATCH_LP2D_BENCH_FAST=1` shrinks the request counts for CI.

use std::time::Duration;

use batch_lp2d::bench::loadgen::{
    absorb_into_profile, json_record, merge_into_bench_json, run_scenario, table, LoadgenOpts,
};
use batch_lp2d::coordinator::{BackendSpec, ClosePolicy};
use batch_lp2d::gen::scenarios::Scenario;
use batch_lp2d::runtime::default_artifact_dir;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = std::env::var_os("BATCH_LP2D_BENCH_FAST").is_some();
    let mut scenarios: Vec<Scenario> = Scenario::ALL.to_vec();
    let mut opts = LoadgenOpts {
        requests: if fast { 1_500 } else { 6_000 },
        ..LoadgenOpts::default()
    };
    let mut shards = 0usize;
    let mut gate_p99_ms: Option<f64> = None;
    let mut gate_shed: Option<usize> = None;
    let mut metrics_out: Option<String> = None;

    let mut i = 0usize;
    while i < args.len() {
        let flag = args[i].clone();
        let mut value = || -> Option<String> {
            i += 1;
            args.get(i).cloned()
        };
        match flag.as_str() {
            "--scenario" => {
                scenarios = Scenario::parse_list(&value().unwrap_or_default())?;
            }
            "--requests" => {
                opts.requests = value().and_then(|v| v.parse().ok()).unwrap_or(opts.requests);
            }
            "--rate" => {
                opts.rate = value().and_then(|v| v.parse().ok()).unwrap_or(opts.rate);
            }
            "--shards" => {
                shards = value().and_then(|v| v.parse().ok()).unwrap_or(0);
            }
            "--backends" => {
                opts.backends = BackendSpec::parse_list(&value().unwrap_or_default())?;
            }
            "--depth" => {
                opts.depth = value().and_then(|v| v.parse().ok()).unwrap_or(opts.depth);
            }
            "--policy" => {
                opts.policy = ClosePolicy::parse(&value().unwrap_or_default())?;
            }
            "--max-queue" => {
                opts.max_queue =
                    value().and_then(|v| v.parse().ok()).unwrap_or(opts.max_queue);
            }
            "--slo-ms" => {
                if let Some(ms) = value().and_then(|v| v.parse().ok()) {
                    opts.slo = Duration::from_millis(ms);
                }
            }
            "--bulk-slo-ms" => {
                if let Some(ms) = value().and_then(|v| v.parse().ok()) {
                    opts.bulk_slo = Duration::from_millis(ms);
                }
            }
            "--replay-speed" => {
                if let Some(x) = value().and_then(|v| v.parse::<f64>().ok()) {
                    anyhow::ensure!(
                        x > 0.0 && x.is_finite(),
                        "--replay-speed must be positive"
                    );
                    opts.replay_speed = x;
                }
            }
            "--gate-p99-ms" => {
                gate_p99_ms = value().and_then(|v| v.parse().ok());
            }
            "--gate-shed" => {
                gate_shed = value().and_then(|v| v.parse().ok());
            }
            "--metrics-out" => {
                metrics_out = value();
            }
            // cargo bench passes through its own flags (e.g. --bench);
            // ignore anything unrecognized rather than failing the run.
            _ => {}
        }
        i += 1;
    }
    // `--shards N` without an explicit mix = N single-thread CPU shards
    // (portable; use --backends for engines or heterogeneous sets).
    if opts.backends.is_empty() && shards > 0 {
        opts.backends = vec![BackendSpec::Cpu; shards];
    }

    println!(
        "## loadgen: {} scenario(s), {} requests each at base rate {:.0}/s, policy {}",
        scenarios.len(),
        opts.requests,
        opts.rate,
        opts.policy.as_str()
    );
    let dir = default_artifact_dir();
    let mut reports = Vec::new();
    for sc in scenarios {
        let r = run_scenario(&dir, sc, &opts)?;
        println!(
            "{:<11} {:>6} ok  {:>5} shed  p99 {:>8.3} ms  queue p99 {:>8.3} ms  \
             {:>7.0} LPs/s  occ {:.2}  adaptive closes {}",
            r.scenario,
            r.completed,
            r.shed(),
            r.p99_ms,
            r.queue_p99_ms,
            r.throughput_lps,
            r.mean_occupancy,
            r.adaptive_closes,
        );
        reports.push(r);
    }

    let t = table(&reports);
    println!("\n{}", t.to_markdown());

    std::fs::write("LOADGEN_table.md", t.to_markdown())
        .map_err(|e| anyhow::anyhow!("cannot write LOADGEN_table.md: {e}"))?;
    let records: Vec<String> = reports.iter().map(json_record).collect();
    merge_into_bench_json(std::path::Path::new("BENCH_pipeline.json"), &records)?;
    println!(
        "wrote LOADGEN_table.md and merged {} record(s) into BENCH_pipeline.json",
        records.len()
    );
    // Second calibration source: a homogeneous shard mix attributes its
    // measured per-class serving costs unambiguously to one backend kind,
    // so feed them into the tune profile next to the offline grid fits.
    let mix = if opts.backends.is_empty() {
        LoadgenOpts::default_backends()
    } else {
        opts.backends.clone()
    };
    match absorb_into_profile(std::path::Path::new("TUNE_profile.json"), &mix, &reports)? {
        Some(n) => println!("absorbed {n} serving observation(s) into TUNE_profile.json"),
        None => println!("heterogeneous mix: serving observations not attributed to a backend"),
    }
    // `--metrics-out`: the last scenario's snapshot as Prometheus text —
    // the loadgen-side counterpart of `serve --metrics-out`.
    if let (Some(path), Some(last)) = (&metrics_out, reports.last()) {
        let shard_names: Vec<String> = mix.iter().map(|s| s.key()).collect();
        batch_lp2d::obs::export::write_metrics_exposition(
            std::path::Path::new(path),
            &last.snapshot,
            &shard_names,
        )
        .map_err(|e| anyhow::anyhow!("cannot write {path}: {e}"))?;
        println!("wrote Prometheus exposition ({}) -> {path}", last.scenario);
    }

    // Replay gate: bound the tail and the shed count per scenario. The
    // artifacts above are written first so a failing run still uploads
    // them for inspection.
    if gate_p99_ms.is_some() || gate_shed.is_some() {
        let mut violations = Vec::new();
        for r in &reports {
            if let Some(bound) = gate_p99_ms {
                if r.p99_ms > bound {
                    violations.push(format!(
                        "{}: p99 {:.3} ms > {bound:.3} ms",
                        r.scenario, r.p99_ms
                    ));
                }
            }
            if let Some(bound) = gate_shed {
                if r.shed() > bound {
                    violations.push(format!("{}: shed {} > {bound}", r.scenario, r.shed()));
                }
            }
        }
        anyhow::ensure!(
            violations.is_empty(),
            "loadgen gate FAILED:\n  {}",
            violations.join("\n  ")
        );
        println!(
            "gate OK: {} scenario(s) within p99 {} / shed {}",
            reports.len(),
            gate_p99_ms.map_or("-".to_string(), |b| format!("{b:.0} ms")),
            gate_shed.map_or("-".to_string(), |b| b.to_string())
        );
    }
    Ok(())
}

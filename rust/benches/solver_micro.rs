//! Microbenchmarks of the CPU substrate: per-solver single-problem cost
//! across sizes, multicore batch scaling, packing throughput, and the
//! double-buffered pipeline's overlap win. Complements the figure benches
//! with component-level numbers for the perf log.
//!
//! Emits `BENCH_pipeline.json` (throughput + memory fraction + overlap) so
//! the perf trajectory is tracked across PRs.

use batch_lp2d::bench::{bench, report_line, BenchOpts};
use batch_lp2d::gen;
use batch_lp2d::lp::types::Problem;
use batch_lp2d::runtime::pack::{self, PackedBatch};
use batch_lp2d::runtime::stream::{run_pipelined, StageWorker};
use batch_lp2d::runtime::{
    default_artifact_dir, Backend, BatchCpuBackend, CpuShardExecutor, Engine, Manifest,
    PipelineDepth, ShardedEngine, SimdCpuBackend, SimdCpuF32Backend, Variant,
};
use batch_lp2d::solvers::{batch_cpu, batch_cpu::Algo, seidel, simplex};
use batch_lp2d::util::{Rng, Timer};

/// Pipeline worker over the CPU substrate: the stage thread packs chunks
/// into wire format (the Fig-5 "memory management" cost) while the caller
/// thread solves them — the same overlap `Engine::solve_stream` gets from
/// PJRT, runnable without artifacts.
struct CpuStage<'a> {
    pool: Vec<PackedBatch>,
    rng: Rng,
    _tie: std::marker::PhantomData<&'a ()>,
}

impl<'a> StageWorker for CpuStage<'a> {
    type Chunk = &'a [Problem];
    type Staged = (PackedBatch, &'a [Problem]);
    type Raw = PackedBatch;
    type Out = ();

    fn stage(&mut self, _idx: usize, chunk: &'a [Problem]) -> anyhow::Result<Self::Staged> {
        let mut pb = self.pool.pop().unwrap_or_else(PackedBatch::empty);
        let m = chunk.iter().map(|p| p.m()).max().unwrap_or(1);
        pack::pack_into(chunk, chunk.len(), m, Some(&mut self.rng), &mut pb)?;
        Ok((pb, chunk))
    }

    fn finish(&mut self, _idx: usize, pb: PackedBatch) -> anyhow::Result<()> {
        self.pool.push(pb);
        Ok(())
    }
}

fn pipeline_report(problems: &[Problem], chunk: usize, threads: usize) -> String {
    let chunks: Vec<&[Problem]> = problems.chunks(chunk).collect();
    let n_chunks = chunks.len();

    // Serial reference: pack then solve, chunk after chunk, one thread's
    // worth of wall time with no overlap.
    let mut pb = PackedBatch::empty();
    let mut rng = Rng::new(21);
    let mut pack_ns = 0u64;
    let mut solve_ns = 0u64;
    for c in &chunks {
        let m = c.iter().map(|p| p.m()).max().unwrap_or(1);
        let t = Timer::start();
        pack::pack_into(*c, c.len(), m, Some(&mut rng), &mut pb).expect("pack");
        pack_ns += t.elapsed_ns();
        let t = Timer::start();
        std::hint::black_box(batch_cpu::solve_batch(c, Algo::Seidel, threads, 7));
        solve_ns += t.elapsed_ns();
    }
    let serial_ns = pack_ns + solve_ns;

    // Pipelined: stage thread packs chunk k+1 while we solve chunk k.
    let worker = CpuStage {
        pool: vec![PackedBatch::empty(), PackedBatch::empty(), PackedBatch::empty()],
        rng: Rng::new(21),
        _tie: std::marker::PhantomData,
    };
    let (result, _, stats) =
        run_pipelined(chunks.iter().copied(), worker, 2, |_, (pb, probs)| {
            std::hint::black_box(batch_cpu::solve_batch(probs, Algo::Seidel, threads, 7));
            Ok(pb)
        });
    result.expect("pipeline");

    let lps = problems.len() as f64 / (stats.critical_path_ns.max(1) as f64 / 1e9);
    let mem_frac = pack_ns as f64 / serial_ns.max(1) as f64;
    let speedup = serial_ns as f64 / stats.critical_path_ns.max(1) as f64;
    println!(
        "pipeline: {n_chunks} chunks x {chunk} LPs  serial {:.3} ms  pipelined {:.3} ms  \
         speedup {speedup:.3}x  overlap {:.3}",
        serial_ns as f64 / 1e6,
        stats.critical_path_ns as f64 / 1e6,
        stats.overlap_ratio(),
    );
    format!(
        "{{\n  \"bench\": \"pipeline_cpu\",\n  \"chunks\": {n_chunks},\n  \"chunk_size\": {chunk},\n  \
         \"throughput_lps\": {lps:.1},\n  \"memory_fraction\": {mem_frac:.4},\n  \
         \"serial_ms\": {:.3},\n  \"pipelined_ms\": {:.3},\n  \"overlap_speedup\": {speedup:.4},\n  \
         \"stage_busy_ms\": {:.3},\n  \"execute_busy_ms\": {:.3}\n}}",
        serial_ns as f64 / 1e6,
        stats.critical_path_ns as f64 / 1e6,
        stats.stage_busy_ns as f64 / 1e6,
        stats.execute_busy_ns as f64 / 1e6,
    )
}

/// Shard counts the sweep reports (the CI perf gate tracks each).
const SHARD_COUNTS: [usize; 3] = [1, 2, 4];

/// Pipeline depths the sweep reports (the CI perf gate tracks each).
const DEPTHS: [usize; 3] = [2, 3, 4];

/// Synthetic bucket inventory for the chunk policy; the CPU executors
/// never open bucket files.
fn cpu_manifest() -> Manifest {
    let text = "variant\tbatch\tm\tblock_b\tchunk\tfile\n\
                rgb\t128\t64\t128\t64\tcpu\n\
                rgb\t256\t64\t128\t64\tcpu\n\
                rgb\t512\t64\t128\t64\tcpu\n\
                rgb\t1024\t64\t128\t64\tcpu\n";
    Manifest::parse(text, std::path::PathBuf::from("cpu-fallback")).expect("manifest")
}

/// Sharded-execution sweep over the deterministic CPU backend: the same
/// workload through `ShardedEngine` at 1/2/4 shards. Runs on any host (no
/// artifacts, no PJRT) — the executors solve straight from the packed
/// bytes — so CI can gate on the shard-scaling trajectory.
fn shard_sweep_reports(problems: &[Problem]) -> Vec<String> {
    let manifest = cpu_manifest();

    let mut out = Vec::new();
    let mut base_ns: Option<u64> = None;
    for shards in SHARD_COUNTS {
        let executors: Vec<CpuShardExecutor> = (0..shards).map(|_| CpuShardExecutor).collect();
        let mut sharded =
            ShardedEngine::from_executors(manifest.clone(), executors).expect("sharded engine");
        let chunk = sharded
            .plan_chunk(Variant::Rgb, problems.len(), 64)
            .expect("chunk plan");
        let mut rng = Rng::new(33);
        let (solutions, report) = sharded
            .solve_all(Variant::Rgb, problems, Some(&mut rng))
            .expect("sharded solve_all");
        assert_eq!(solutions.len(), problems.len());

        let wall_ns = report.timing.critical_path_ns.max(1);
        let base = *base_ns.get_or_insert(wall_ns);
        let lps = problems.len() as f64 / (wall_ns as f64 / 1e9);
        let speedup = base as f64 / wall_ns as f64;
        println!(
            "shards {shards}: chunk {chunk}  {:.3} ms  {:.0} LPs/s  speedup {speedup:.3}x  \
             balance {:.3}",
            wall_ns as f64 / 1e6,
            lps,
            report.balance(),
        );
        out.push(format!(
            "{{\n  \"bench\": \"pipeline_shard_cpu\",\n  \"shards\": {shards},\n  \
             \"chunk_size\": {chunk},\n  \"throughput_lps\": {lps:.1},\n  \
             \"wall_ms\": {:.3},\n  \"speedup_vs_1shard\": {speedup:.4},\n  \
             \"balance\": {:.3}\n}}",
            wall_ns as f64 / 1e6,
            report.balance(),
        ));
    }
    out
}

/// Pipeline-depth sweep over the deterministic CPU backend: the same
/// workload through a 2-shard `ShardedEngine` at staged-queue depths
/// 2/3/4. Like the shard sweep it runs on any host, so the perf gate can
/// track the depth trajectory alongside the shard trajectory.
fn depth_sweep_reports(problems: &[Problem]) -> Vec<String> {
    let manifest = cpu_manifest();
    let mut out = Vec::new();
    let mut base_ns: Option<u64> = None;
    for depth in DEPTHS {
        let executors: Vec<CpuShardExecutor> = (0..2).map(|_| CpuShardExecutor).collect();
        let mut sharded = ShardedEngine::from_executors(manifest.clone(), executors)
            .expect("sharded engine")
            .with_depth(PipelineDepth::new(depth));
        let chunk = sharded
            .plan_chunk(Variant::Rgb, problems.len(), 64)
            .expect("chunk plan");
        let mut rng = Rng::new(33);
        let (solutions, report) = sharded
            .solve_all(Variant::Rgb, problems, Some(&mut rng))
            .expect("sharded solve_all");
        assert_eq!(solutions.len(), problems.len());

        let wall_ns = report.timing.critical_path_ns.max(1);
        let base = *base_ns.get_or_insert(wall_ns);
        let lps = problems.len() as f64 / (wall_ns as f64 / 1e9);
        let speedup = base as f64 / wall_ns as f64;
        println!(
            "depth {depth}: chunk {chunk}  {:.3} ms  {:.0} LPs/s  speedup {speedup:.3}x  \
             steals {}",
            wall_ns as f64 / 1e6,
            lps,
            report.steals(),
        );
        out.push(format!(
            "{{\n  \"bench\": \"pipeline_depth_cpu\",\n  \"depth\": {depth},\n  \
             \"chunk_size\": {chunk},\n  \"throughput_lps\": {lps:.1},\n  \
             \"wall_ms\": {:.3},\n  \"speedup_vs_depth2\": {speedup:.4},\n  \
             \"steals\": {}\n}}",
            wall_ns as f64 / 1e6,
            report.steals(),
        ));
    }
    out
}

/// Single-shard SoA-vs-scalar backend comparison at equal thread counts —
/// the `simd-cpu` acceptance rows (`simd_micro_*` records). Packs one
/// bucket-shaped batch and times `execute_raw` on both backends directly,
/// so the ratio is the kernels', not the dispatch layer's.
fn simd_micro_reports(opts: BenchOpts) -> Vec<String> {
    let manifest = cpu_manifest();
    let threads = batch_cpu::default_threads();
    let mut out = Vec::new();
    for batch in [256usize, 1024] {
        let bucket = manifest.find(Variant::Rgb, batch, 64).expect("bucket").clone();
        let mut rng = Rng::new(11 ^ batch as u64);
        let problems = gen::independent_batch(&mut rng, batch, 64);
        let pb = pack::pack(&problems, bucket.batch, bucket.m, None).expect("pack");

        let mut lps = |backend: &mut dyn Backend, label: String| -> f64 {
            let r = bench(&label, opts, || {
                std::hint::black_box(backend.execute_raw(&bucket, &pb).expect("execute"));
            });
            println!("{}", report_line(&r));
            batch as f64 / (r.mean_ms() / 1e3).max(1e-12)
        };
        let mut scalar = BatchCpuBackend::new(threads);
        let scalar_lps = lps(&mut scalar, format!("batch_cpu/t{threads}/b{batch}"));
        let mut simd = SimdCpuBackend::new(threads);
        let simd_lps = lps(&mut simd, format!("simd_cpu/t{threads}/b{batch}"));
        let speedup = simd_lps / scalar_lps.max(1e-9);
        println!("simd-cpu vs batch-cpu @ batch {batch} x m 64: {speedup:.3}x");
        out.push(format!(
            "{{\n  \"bench\": \"simd_micro_b{batch}\",\n  \"batch\": {batch},\n  \"m\": 64,\n  \
             \"threads\": {threads},\n  \"throughput_lps\": {simd_lps:.1},\n  \
             \"batch_cpu_lps\": {scalar_lps:.1},\n  \"speedup_vs_batch_cpu\": {speedup:.4}\n}}"
        ));
    }
    out
}

/// Wire-precision twin of `simd_micro_reports`: the 16-lane f32 kernel
/// (`simd-cpu-f32`) against the 8-lane f64 kernel at equal thread counts —
/// the `simd_f32_micro_*` acceptance rows. Same bucket shapes and packed
/// bytes, so the ratio isolates lane width + element width.
fn simd_f32_micro_reports(opts: BenchOpts) -> Vec<String> {
    let manifest = cpu_manifest();
    let threads = batch_cpu::default_threads();
    let mut out = Vec::new();
    for batch in [256usize, 1024] {
        let bucket = manifest.find(Variant::Rgb, batch, 64).expect("bucket").clone();
        let mut rng = Rng::new(11 ^ batch as u64);
        let problems = gen::independent_batch(&mut rng, batch, 64);
        let pb = pack::pack(&problems, bucket.batch, bucket.m, None).expect("pack");

        let mut lps = |backend: &mut dyn Backend, label: String| -> f64 {
            let r = bench(&label, opts, || {
                std::hint::black_box(backend.execute_raw(&bucket, &pb).expect("execute"));
            });
            println!("{}", report_line(&r));
            batch as f64 / (r.mean_ms() / 1e3).max(1e-12)
        };
        let mut f64_kernel = SimdCpuBackend::new(threads);
        let f64_lps = lps(&mut f64_kernel, format!("simd_cpu/t{threads}/b{batch}"));
        let mut f32_kernel = SimdCpuF32Backend::new(threads);
        let f32_lps = lps(&mut f32_kernel, format!("simd_cpu_f32/t{threads}/b{batch}"));
        let speedup = f32_lps / f64_lps.max(1e-9);
        println!("simd-cpu-f32 vs simd-cpu @ batch {batch} x m 64: {speedup:.3}x");
        out.push(format!(
            "{{\n  \"bench\": \"simd_f32_micro_b{batch}\",\n  \"batch\": {batch},\n  \"m\": 64,\n  \
             \"threads\": {threads},\n  \"throughput_lps\": {f32_lps:.1},\n  \
             \"simd_f64_lps\": {f64_lps:.1},\n  \"speedup_vs_f64\": {speedup:.4}\n}}"
        ));
    }
    out
}

/// Engine-path shard sweep; empty when artifacts (or the real PJRT
/// backend) are unavailable.
fn engine_shard_sweep(problems: &[Problem]) -> Vec<String> {
    let mut out = Vec::new();
    let mut base_ns: Option<u64> = None;
    for shards in SHARD_COUNTS {
        let Ok(mut sharded) = ShardedEngine::new(default_artifact_dir(), shards) else {
            return out;
        };
        let mut rng = Rng::new(5);
        // Warm every shard's executable cache outside the timed run.
        if sharded.solve_all(Variant::Rgb, problems, Some(&mut rng)).is_err() {
            return out;
        }
        let mut rng = Rng::new(5);
        let Ok((_, report)) = sharded.solve_all(Variant::Rgb, problems, Some(&mut rng)) else {
            return out;
        };
        let wall_ns = report.timing.critical_path_ns.max(1);
        let base = *base_ns.get_or_insert(wall_ns);
        let lps = problems.len() as f64 / (wall_ns as f64 / 1e9);
        println!(
            "shards(engine) {shards}: {:.3} ms  {:.0} LPs/s  speedup {:.3}x",
            wall_ns as f64 / 1e6,
            lps,
            base as f64 / wall_ns as f64,
        );
        out.push(format!(
            "{{\n  \"bench\": \"pipeline_shard_engine\",\n  \"shards\": {shards},\n  \
             \"throughput_lps\": {lps:.1},\n  \"wall_ms\": {:.3},\n  \
             \"speedup_vs_1shard\": {:.4}\n}}",
            wall_ns as f64 / 1e6,
            base as f64 / wall_ns as f64,
        ));
    }
    out
}

/// Engine-path pipeline numbers; None when artifacts (or the real PJRT
/// backend) are unavailable.
fn engine_pipeline_report(problems: &[Problem], chunk: usize) -> Option<String> {
    let engine = Engine::new(default_artifact_dir()).ok()?;
    let chunks: Vec<&[Problem]> = problems.chunks(chunk).collect();

    // Warm the executable cache so the serial baseline doesn't charge the
    // one-time XLA compile to "pipelining win".
    let mut rng = Rng::new(5);
    engine.solve(Variant::Rgb, chunks[0], Some(&mut rng)).ok()?;

    let mut rng = Rng::new(5);
    let mut serial = batch_lp2d::runtime::ExecTiming::default();
    for c in &chunks {
        let (_, t) = engine.solve(Variant::Rgb, *c, Some(&mut rng)).ok()?;
        serial.accumulate(&t);
    }
    let mut rng = Rng::new(5);
    let (_, stream) = engine
        .solve_stream(Variant::Rgb, chunks.iter().copied(), Some(&mut rng))
        .ok()?;
    let lps = problems.len() as f64 / (stream.critical_path_ns.max(1) as f64 / 1e9);
    println!(
        "pipeline(engine): serial {:.3} ms  pipelined {:.3} ms  overlap {:.3}",
        serial.critical_path_ns as f64 / 1e6,
        stream.critical_path_ns as f64 / 1e6,
        stream.overlap_ratio(),
    );
    Some(format!(
        "{{\n  \"bench\": \"pipeline_engine_rgb\",\n  \"chunks\": {},\n  \"chunk_size\": {chunk},\n  \
         \"throughput_lps\": {lps:.1},\n  \"memory_fraction\": {:.4},\n  \
         \"serial_ms\": {:.3},\n  \"pipelined_ms\": {:.3},\n  \"overlap_speedup\": {:.4}\n}}",
        chunks.len(),
        stream.memory_fraction(),
        serial.critical_path_ns as f64 / 1e6,
        stream.critical_path_ns as f64 / 1e6,
        serial.critical_path_ns as f64 / stream.critical_path_ns.max(1) as f64,
    ))
}

fn main() {
    let opts = BenchOpts::from_env();
    let mut rng = Rng::new(7);

    println!("## per-solver single-problem cost");
    for m in [16usize, 64, 256, 1024] {
        let p = gen::feasible(&mut rng, m);
        let mut r1 = Rng::new(1);
        println!("{}", report_line(&bench(&format!("seidel/m{m}"), opts, || {
            std::hint::black_box(seidel::solve(&p, &mut r1));
        })));
        if m <= 256 {
            println!("{}", report_line(&bench(&format!("simplex/m{m}"), opts, || {
                std::hint::black_box(simplex::solve(&p));
            })));
        }
    }

    println!("\n## multicore batch scaling (seidel, batch 4096 x m 64)");
    let problems = gen::independent_batch(&mut rng, 4096, 64);
    for threads in [1usize, 2, 4, 8] {
        println!("{}", report_line(&bench(&format!("batch_cpu/t{threads}"), opts, || {
            std::hint::black_box(batch_cpu::solve_batch(&problems, Algo::Seidel, threads, 0));
        })));
    }

    println!("\n## packing throughput (4096 x m 64 -> bucket)");
    let mut prng = Rng::new(3);
    println!("{}", report_line(&bench("pack/shuffled", opts, || {
        std::hint::black_box(pack::pack(&problems, 4096, 64, Some(&mut prng)).unwrap());
    })));
    println!("{}", report_line(&bench("pack/plain", opts, || {
        std::hint::black_box(pack::pack(&problems, 4096, 64, None).unwrap());
    })));

    println!("\n## double-buffered pipeline (pack overlapped with solve)");
    // Single-threaded solve keeps the execute stage comparable to the pack
    // stage so the overlap is visible on any core count.
    let json_cpu = pipeline_report(&problems, 512, 1);
    let json_engine = engine_pipeline_report(&problems, 512);

    println!("\n## sharded execution sweep (weighted dispatch + stealing)");
    let json_shards = shard_sweep_reports(&problems);
    let json_engine_shards = engine_shard_sweep(&problems);

    println!("\n## pipeline-depth sweep (2 CPU shards, depth 2/3/4)");
    let json_depths = depth_sweep_reports(&problems);

    println!("\n## simd-cpu vs batch-cpu single-shard (equal threads, m 64)");
    let json_simd = simd_micro_reports(opts);

    println!("\n## simd-cpu-f32 vs simd-cpu single-shard (equal threads, m 64)");
    let json_simd_f32 = simd_f32_micro_reports(opts);

    let mut entries: Vec<String> = vec![json_cpu];
    entries.extend(json_engine);
    entries.extend(json_shards);
    entries.extend(json_engine_shards);
    entries.extend(json_depths);
    entries.extend(json_simd);
    entries.extend(json_simd_f32);
    let mut body = String::from("[\n");
    body.push_str(&entries.join(",\n"));
    body.push_str("\n]\n");
    match std::fs::write("BENCH_pipeline.json", &body) {
        Ok(()) => println!("wrote BENCH_pipeline.json"),
        Err(e) => eprintln!("could not write BENCH_pipeline.json: {e}"),
    }
}

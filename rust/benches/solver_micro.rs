//! Microbenchmarks of the CPU substrate: per-solver single-problem cost
//! across sizes, multicore batch scaling, packing throughput. Complements
//! the figure benches with component-level numbers for the perf log.

use batch_lp2d::bench::{bench, report_line, BenchOpts};
use batch_lp2d::gen;
use batch_lp2d::runtime::pack;
use batch_lp2d::solvers::{batch_cpu, batch_cpu::Algo, seidel, simplex};
use batch_lp2d::util::Rng;

fn main() {
    let opts = BenchOpts::from_env();
    let mut rng = Rng::new(7);

    println!("## per-solver single-problem cost");
    for m in [16usize, 64, 256, 1024] {
        let p = gen::feasible(&mut rng, m);
        let mut r1 = Rng::new(1);
        println!("{}", report_line(&bench(&format!("seidel/m{m}"), opts, || {
            std::hint::black_box(seidel::solve(&p, &mut r1));
        })));
        if m <= 256 {
            println!("{}", report_line(&bench(&format!("simplex/m{m}"), opts, || {
                std::hint::black_box(simplex::solve(&p));
            })));
        }
    }

    println!("\n## multicore batch scaling (seidel, batch 4096 x m 64)");
    let problems = gen::independent_batch(&mut rng, 4096, 64);
    for threads in [1usize, 2, 4, 8] {
        println!("{}", report_line(&bench(&format!("batch_cpu/t{threads}"), opts, || {
            std::hint::black_box(batch_cpu::solve_batch(&problems, Algo::Seidel, threads, 0));
        })));
    }

    println!("\n## packing throughput (4096 x m 64 -> bucket)");
    let mut prng = Rng::new(3);
    println!("{}", report_line(&bench("pack/shuffled", opts, || {
        std::hint::black_box(pack::pack(&problems, 4096, 64, Some(&mut prng)).unwrap());
    })));
    println!("{}", report_line(&bench("pack/plain", opts, || {
        std::hint::black_box(pack::pack(&problems, 4096, 64, None).unwrap());
    })));
}

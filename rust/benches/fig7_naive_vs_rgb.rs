//! Figures 7a-7b: speedup of optimized RGB over NaiveRGB (kernel time only)
//! vs LP size, at batch 1024 and 4096(-scaled-from-32768).
//! `cargo bench --bench fig7_naive_vs_rgb`

use batch_lp2d::bench::figures::{self, FigureCtx};
use batch_lp2d::runtime::{default_artifact_dir, Engine};

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(default_artifact_dir())?;
    let ctx = FigureCtx::new(&engine);
    for (name, batch) in [("7a", 1024usize), ("7b", 4096)] {
        eprintln!("figure {name}: batch {batch}");
        let t = figures::fig7(&ctx, batch, figures::SIZES)?;
        println!("\n## Figure {name} (naive/rgb kernel speedup, batch {batch})\n");
        print!("{}", t.to_markdown());
    }
    Ok(())
}

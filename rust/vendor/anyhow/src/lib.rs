//! Minimal offline drop-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this vendored crate
//! provides exactly the surface `batch_lp2d` uses:
//!
//! * [`Error`] / [`Result`] — a message-chain error type (`Send + Sync`).
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros.
//! * `Error::context` and the [`Context`] extension trait.
//! * Blanket `From<E: std::error::Error>` so `?` converts std errors.
//!
//! Deliberately NOT implemented: backtraces and `downcast` (nothing in the
//! workspace uses them). Like real anyhow, `Error` does not implement
//! `std::error::Error` itself — that is what keeps the blanket `From`
//! coherent.

use std::fmt;

/// A chain of error messages; the head is the most recent context.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a pre-formatted message (used by the macros).
    pub fn from_msg(msg: String) -> Error {
        Error { msg, source: None }
    }

    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error::from_msg(m.to_string())
    }

    /// Wrap with an outer context message (matches `anyhow::Error::context`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur.msg.as_str())
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut source = self.source.as_deref();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = source {
            write!(f, "\n    {}", e.msg)?;
            source = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_msg(e.to_string())
    }
}

/// `.context(..)` / `.with_context(..)` on `Result`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::from_msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::from_msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return ::std::result::Result::Err($crate::Error::from_msg(
                ::std::concat!("condition failed: ", ::std::stringify!($cond)).to_string(),
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let n: i32 = s.parse()?; // std error converts via blanket From
        ensure!(n >= 0, "negative: {n}");
        Ok(n)
    }

    #[test]
    fn from_std_error_and_ensure() {
        assert_eq!(parse("3").unwrap(), 3);
        assert!(parse("x").is_err());
        assert_eq!(parse("-1").unwrap_err().to_string(), "negative: -1");
    }

    #[test]
    fn context_chains() {
        let e = anyhow!("inner {}", 1).context("outer");
        assert_eq!(e.to_string(), "outer");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["outer", "inner 1"]);
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }

    #[test]
    fn bail_returns() {
        fn f() -> Result<()> {
            bail!("boom {}", 7);
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 7");
    }
}

//! Offline stub of the `xla` PJRT bindings.
//!
//! The offline build environment ships no libpjrt, so this crate mirrors
//! exactly the API surface `batch_lp2d::runtime::engine` consumes — enough
//! for the full stack (runtime, coordinator, benches, examples) to compile
//! and for every non-PJRT test to run. Constructing a [`PjRtClient`]
//! returns an explicit "backend unavailable" error, which the engine
//! surfaces from `Engine::new`; all PJRT-touching tests gate on compiled
//! artifacts being present and skip cleanly.
//!
//! To execute the AOT artifacts for real, replace this path dependency in
//! `rust/Cargo.toml` with the actual `xla` bindings (the types and method
//! signatures here match their call shapes 1:1, so no engine change is
//! needed).

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Stub error: every device-touching call fails with this.
#[derive(Clone, Debug)]
pub struct Error(String);

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT backend unavailable (offline `xla` stub; swap in the \
             real bindings in rust/Cargo.toml to execute artifacts)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Element dtypes the engine stages host buffers as.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PrimitiveType {
    F32,
    S32,
}

/// Rust scalar types a [`Literal`] can decode to.
pub trait NativeType: Copy + Default {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side tensor buffer handle.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: PrimitiveType,
    dims: Vec<usize>,
}

impl Literal {
    /// Allocate a zeroed literal of the given shape.
    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Literal {
        Literal { ty, dims: dims.to_vec() }
    }

    pub fn primitive_type(&self) -> PrimitiveType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Copy a host slice into the literal's backing store.
    pub fn copy_raw_from(&mut self, _src: &[f32]) -> Result<()> {
        Err(Error::unavailable("Literal::copy_raw_from"))
    }

    /// Decode the literal into a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    /// Split a 2-tuple literal into its elements.
    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(Error::unavailable("Literal::to_tuple2"))
    }
}

/// Parsed HLO module (text form).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

/// A computation ready for compilation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle. The real binding wraps a non-atomic `Rc` and raw
/// PJRT pointers (not `Sync`); the stub mirrors that so the engine's thread
/// model is exercised identically in both builds.
pub struct PjRtClient {
    _not_sync: std::marker::PhantomData<std::rc::Rc<()>>,
}

impl PjRtClient {
    /// Connect to the CPU PJRT plugin. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _not_sync: std::marker::PhantomData<std::rc::Rc<()>>,
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals; returns per-device,
    /// per-output buffers.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _not_sync: std::marker::PhantomData<std::rc::Rc<()>>,
}

impl PjRtBuffer {
    /// Synchronously copy the device buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("backend unavailable"));
    }

    #[test]
    fn literal_shape_roundtrip() {
        let l = Literal::create_from_shape(PrimitiveType::F32, &[4, 8, 4]);
        assert_eq!(l.dims(), &[4, 8, 4]);
        assert_eq!(l.primitive_type(), PrimitiveType::F32);
        assert!(l.to_vec::<f32>().is_err());
    }
}
